"""Pallas TPU flash-decode kernels: query tokens vs a long KV cache.

Decode is memory-bound (EXPERIMENTS.md §Roofline: every decode_32k /
long_500k pair), so these kernels stream the grouped KV cache HBM→VMEM at
most once, keep the GQA query block resident, and support:

  * grouped-query attention without cache expansion (q reshaped to
    (Hkv, G, D); the cache is read once, not ×G);
  * block-skipping via scalar-prefetched block tables — the decode-phase
    pattern-sharing extension: kv blocks outside the keep-set are never
    streamed (same splash machinery as the prefill kernel);
  * running-max online softmax over sequential kv blocks.

Three entry points, from validation to production:

  ``flash_decode``          single-sample (Hkv, S/bs) grid, dense streaming,
                            per-head token ``keep`` mask (validation kernel).
  ``flash_decode_sparse``   single-sample block-skipping variant; rebuilds
                            its block table from the token mask on every call
                            (validation of the skipping machinery only).
  ``flash_decode_plan``     the serving path: batched (B, Hkv, W) grid
                            consuming a prebuilt :class:`DecodePlan` layer
                            slice — tables are built **once per batch**
                            (``repro.serving.decode_plan``), not per decode
                            step, and the backend auto-dispatches: compiled
                            Pallas kernel on TPU, grouped-einsum fallback
                            elsewhere (mirroring ``sparse_attention_fn``).

Validated against :func:`repro.kernels.ref.decode_attention_ref` / the
grouped einsum in interpret mode.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")

DECODE_IMPLS = ("auto", "kernel", "einsum")


class DecodePlan(NamedTuple):
    """Splash block tables for sparse decode — the kernel-side contract.

    Built once per served batch (``repro.serving.decode_plan``) from the
    post-prefill pattern dictionary; leaves may carry a leading layer axis
    (``(L, B, …)``, sliced per layer by the decode scan) or be a single
    layer's slice (``(B, …)``).

      indices:    (…, B, Hkv, W)  int32 — per-(batch, kv-head) active block
                  ids, ascending, padded by repeating the last kept id (the
                  Pallas pipeline elides the repeated DMA).
      counts:     (…, B, Hkv)     int32 — kept entries per table row.
      keep_heads: (…, B, Hkv, NB, G) bool — per-*query-head* block keep bits
                  refining the union table within each GQA group (a visited
                  block can still be masked for some of the group's heads).

    Everything is O(B·Hkv·NB) per layer — the O(B·H·S) token keep-mask the
    engine used to thread through every decode step is gone.

    The batch axis is a set of *slots* under the continuous-batching
    scheduler: the ``valid`` mask the kernels consume is per-row (each slot
    is at its own decode position), table rows are spliced in-flight when a
    slot is refilled (``repro.serving.decode_plan.update_plan_slot``), and
    an unoccupied slot's empty table (``counts == 0``, keep bits all False)
    makes it inert — the kernel's empty-keep contract emits exact zeros and
    the einsum fallback masks everything, so occupied rows are bitwise
    independent of slot churn.
    """

    indices: jnp.ndarray
    counts: jnp.ndarray
    keep_heads: jnp.ndarray


def _auto_interpret(interpret: Optional[bool]) -> bool:
    """Backend-auto: compile the kernel on TPU, interpret elsewhere."""
    return jax.default_backend() != "tpu" if interpret is None else interpret


def resolve_decode_impl(impl: str) -> str:
    """Map a decode ``impl`` name to a concrete backend.

    ``auto`` is the serving-safe policy: the compiled block-skipping kernel
    on TPU, the grouped-einsum fallback elsewhere — jitting the Pallas
    *interpreter* at serving cache lengths unrolls its grid into the HLO, so
    interpret mode stays a validation tool unless asked for via ``kernel``.
    """
    if impl == "auto":
        return "kernel" if jax.default_backend() == "tpu" else "einsum"
    if impl not in DECODE_IMPLS:
        raise ValueError(f"unknown decode impl {impl!r}; "
                         f"expected one of {DECODE_IMPLS}")
    return impl


def _kernel(q_ref, k_ref, v_ref, mask_ref,      # VMEM tiles
            out_ref,                             # output
            acc_ref, m_ref, l_ref,               # scratch
            *, block_kv: int, scale: float, kv_steps: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)             # (G, D)
    k = k_ref[0].astype(jnp.float32)             # (bs, D)
    v = v_ref[0].astype(jnp.float32)             # (bs, Dv)
    valid = mask_ref[0]                          # (G, bs) bool

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                          # (G, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # rows with no valid key yet keep m = -inf; guard the rescale
    alpha = jnp.where(jnp.isfinite(m_prev),
                      jnp.exp(m_prev - jnp.where(jnp.isfinite(m_new),
                                                 m_new, 0.0)), 0.0)
    p = jnp.where(valid, jnp.exp(s - jnp.where(jnp.isfinite(m_new),
                                               m_new, 0.0)), 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == kv_steps - 1)
    def _finalize():
        out_ref[0] = (acc_ref[...] /
                      jnp.maximum(l_ref[...], 1e-30)).astype(out_ref.dtype)


def flash_decode(
    q: jnp.ndarray,             # (H, D) one token's queries
    cache_k: jnp.ndarray,       # (Hkv, S, D)
    cache_v: jnp.ndarray,       # (Hkv, S, Dv)
    mask: jnp.ndarray,          # (H, S) bool — length ∧ window ∧ keep
    *,
    block_kv: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Returns (H, Dv)."""
    h, d = q.shape
    hkv, s, dv = cache_v.shape
    g = h // hkv
    kv_steps = s // block_kv
    scale = 1.0 / (d ** 0.5)

    qg = q.reshape(hkv, g, d)
    maskg = mask.reshape(hkv, g, s)

    kernel = functools.partial(_kernel, block_kv=block_kv, scale=scale,
                               kv_steps=kv_steps)
    out = pl.pallas_call(
        kernel,
        grid=(hkv, kv_steps),
        in_specs=[
            pl.BlockSpec((1, g, d), lambda h_, j: (h_, 0, 0)),
            pl.BlockSpec((1, block_kv, d), lambda h_, j: (h_, j, 0)),
            pl.BlockSpec((1, block_kv, dv), lambda h_, j: (h_, j, 0)),
            pl.BlockSpec((1, g, block_kv), lambda h_, j: (h_, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, g, dv), lambda h_, j: (h_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((hkv, g, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, dv), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
        interpret=_auto_interpret(interpret),
    )(qg, cache_k, cache_v, maskg)
    return out.reshape(h, dv)


def _sparse_kernel(idx_ref, cnt_ref,
                   q_ref, k_ref, v_ref, mask_ref,
                   out_ref, acc_ref, m_ref, l_ref,
                   *, block_kv: int, scale: float, w_steps: int):
    h = pl.program_id(0)
    w = pl.program_id(1)

    @pl.when(w == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    valid_step = w < cnt_ref[h]

    @pl.when(valid_step)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        valid = mask_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - safe), 0.0)
        p = jnp.where(valid, jnp.exp(s - safe), 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, 1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(w == w_steps - 1)
    def _finalize():
        out_ref[0] = (acc_ref[...] /
                      jnp.maximum(l_ref[...], 1e-30)).astype(out_ref.dtype)


def flash_decode_sparse(
    q: jnp.ndarray,             # (H, D)
    cache_k: jnp.ndarray,       # (Hkv, S, D)
    cache_v: jnp.ndarray,       # (Hkv, S, Dv)
    mask: jnp.ndarray,          # (H, S) bool — already includes keep-set
    *,
    block_kv: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Block-skipping variant: kv blocks whose keep-mask is all-False for a
    kv-head group are never streamed (scalar-prefetched block tables — the
    decode analogue of the prefill splash kernel).

    NOTE: rebuilds the block-table argsort from the token mask on every call
    — fine for validation, wrong for serving.  The serving path is
    :func:`flash_decode_plan`, which consumes tables built once per batch.
    """
    h, d = q.shape
    hkv, s, dv = cache_v.shape
    g = h // hkv
    nb = s // block_kv
    scale = 1.0 / (d ** 0.5)

    qg = q.reshape(hkv, g, d)
    maskg = mask.reshape(hkv, g, s)
    # per-kv-head active block table (union over the group's heads)
    blk_any = jnp.any(maskg.reshape(hkv, g, nb, block_kv), axis=(1, 3))
    cols = jnp.arange(nb, dtype=jnp.int32)
    key = jnp.where(blk_any, cols, cols + nb)
    order = jnp.argsort(key, axis=-1).astype(jnp.int32)
    counts = jnp.sum(blk_any, axis=-1).astype(jnp.int32)
    last = jnp.take_along_axis(order,
                               jnp.maximum(counts - 1, 0)[:, None], -1)
    widx = jnp.arange(nb, dtype=jnp.int32)
    indices = jnp.where(widx[None, :] < counts[:, None], order, last)

    kernel = functools.partial(_sparse_kernel, block_kv=block_kv,
                               scale=scale, w_steps=nb)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(hkv, nb),
        in_specs=[
            pl.BlockSpec((1, g, d), lambda h_, w, idx, cnt: (h_, 0, 0)),
            pl.BlockSpec((1, block_kv, d),
                         lambda h_, w, idx, cnt: (h_, idx[h_, w], 0)),
            pl.BlockSpec((1, block_kv, dv),
                         lambda h_, w, idx, cnt: (h_, idx[h_, w], 0)),
            pl.BlockSpec((1, g, block_kv),
                         lambda h_, w, idx, cnt: (h_, 0, idx[h_, w])),
        ],
        out_specs=pl.BlockSpec((1, g, dv),
                               lambda h_, w, idx, cnt: (h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, dv), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((hkv, g, dv), q.dtype),
        interpret=_auto_interpret(interpret),
    )(indices, counts, qg, cache_k, cache_v, maskg)
    return out.reshape(h, dv)


# --------------------------------------------------------------------------
# Batched serving kernel: (B, Hkv, W) grid over prebuilt DecodePlan tables
# --------------------------------------------------------------------------

def _batched_kernel(idx_ref, cnt_ref,             # scalar prefetch (SMEM)
                    q_ref, k_ref, v_ref, keep_ref, val_ref,   # VMEM tiles
                    out_ref, acc_ref, m_ref, l_ref,
                    *, scale: float, w_steps: int):
    b = pl.program_id(0)
    h = pl.program_id(1)
    w = pl.program_id(2)

    @pl.when(w == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(w < cnt_ref[b, h])
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)      # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)      # (bs, D)
        v = v_ref[0, 0].astype(jnp.float32)      # (bs, Dv)
        keep = keep_ref[0, 0, 0]                 # (G,) per-head block keep
        tok = val_ref[0]                         # (bs,) slot validity
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        ok = keep[:, None] & tok[None, :]        # (G, bs)
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - safe), 0.0)
        p = jnp.where(ok, jnp.exp(s - safe), 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, 1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(w == w_steps - 1)
    def _finalize():
        # kv-heads with an empty keep-set (counts == 0) emit zeros: l stays 0
        out_ref[0, 0] = (acc_ref[...] /
                         jnp.maximum(l_ref[...], 1e-30)).astype(out_ref.dtype)


def flash_decode_sparse_batched(
    q: jnp.ndarray,             # (B, H, D) one token per sequence
    cache_k: jnp.ndarray,       # (B, Hkv, S, D)
    cache_v: jnp.ndarray,       # (B, Hkv, S, Dv)
    indices: jnp.ndarray,       # (B, Hkv, W) int32 block table
    counts: jnp.ndarray,        # (B, Hkv) int32
    keep_heads: jnp.ndarray,    # (B, Hkv, NB, G) bool per-head block keep
    valid: jnp.ndarray,         # (B, S) bool slot validity (length ∧ ragged)
    *,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Batched GQA block-skipping flash decode over prebuilt tables.

    Grid ``(B, Hkv, W)`` with the W axis sequential; the block tables are
    scalar-prefetched to SMEM so the K/V BlockSpec index_map skips
    masked-out kv blocks — they are never streamed HBM→VMEM — and padded
    steps repeat the previous block id (DMA elided).  The table argsort is
    NOT rebuilt here: tables come from :func:`repro.serving.decode_plan.
    build_decode_plan`, once per batch.

    A kv-head whose table is empty (``counts == 0``) emits zeros for its
    whole query group — the caller guarantees non-empty keep-sets (the plan
    always keeps the dense recent tail).

    Returns (B, H, Dv).
    """
    b, h, d = q.shape
    _, hkv, s, dv = cache_v.shape
    g = h // hkv
    nb = keep_heads.shape[2]
    block_kv = s // nb
    w_steps = indices.shape[-1]
    scale = 1.0 / (d ** 0.5)
    qg = q.reshape(b, hkv, g, d)

    kernel = functools.partial(_batched_kernel, scale=scale, w_steps=w_steps)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, w_steps),
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda b_, h_, w, idx, cnt: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda b_, h_, w, idx, cnt:
                         (b_, h_, idx[b_, h_, w], 0)),
            pl.BlockSpec((1, 1, block_kv, dv),
                         lambda b_, h_, w, idx, cnt:
                         (b_, h_, idx[b_, h_, w], 0)),
            pl.BlockSpec((1, 1, 1, g),
                         lambda b_, h_, w, idx, cnt:
                         (b_, h_, idx[b_, h_, w], 0)),
            pl.BlockSpec((1, block_kv),
                         lambda b_, h_, w, idx, cnt: (b_, idx[b_, h_, w])),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dv),
                               lambda b_, h_, w, idx, cnt: (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, dv), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, dv), q.dtype),
        interpret=_auto_interpret(interpret),
    )(indices, counts, qg, cache_k, cache_v, keep_heads, valid)
    return out.reshape(b, h, dv)


def decode_plan_einsum(
    q: jnp.ndarray,             # (B, H, D)
    cache_k: jnp.ndarray,       # (B, Hkv, S, D)
    cache_v: jnp.ndarray,       # (B, Hkv, S, Dv)
    keep_heads: jnp.ndarray,    # (B, Hkv, NB, G) bool
    valid: jnp.ndarray,         # (B, S) bool
) -> jnp.ndarray:
    """Grouped-einsum fallback consuming the same DecodePlan semantics.

    Contracts the full cache (no block skipping — CPU is a correctness
    path), masking with the per-head block keep bits expanded to token
    granularity *transiently, per layer* — nothing O(L·B·H·S) is ever
    threaded between steps.  Rows with no visible key emit zeros, matching
    the kernel's empty-table behavior.
    """
    b, h, d = q.shape
    _, hkv, s, dv = cache_v.shape
    g = h // hkv
    nb = keep_heads.shape[2]
    scale = 1.0 / (d ** 0.5)
    qg = q.reshape(b, hkv, g, d)
    logits = jnp.einsum("bkgd,bksd->bkgs", qg, cache_k,
                        preferred_element_type=jnp.float32) * scale
    km = jnp.repeat(jnp.moveaxis(keep_heads, -1, -2), s // nb, axis=-1)
    ok = km & valid[:, None, None, :]            # (B, Hkv, G, S)
    logits = jnp.where(ok, logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.where(ok, jnp.exp(logits - m), 0.0)
    denom = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bkgs,bksd->bkgd",
                     jnp.asarray(p / denom, cache_v.dtype), cache_v,
                     preferred_element_type=jnp.float32)
    return jnp.asarray(out, q.dtype).reshape(b, h, dv)


def _plan_einsum_sliced(
    qg: jnp.ndarray,            # (B, Hkv, G, D)
    kg: jnp.ndarray,            # (B, Hkv, W, bs, D) gathered table blocks
    vg: jnp.ndarray,            # (B, Hkv, W, bs, Dv)
    keep_g: jnp.ndarray,        # (B, Hkv, W, G) gathered keep bits
    valid_g: jnp.ndarray,       # (B, Hkv, W, bs) gathered slot validity
    counts: jnp.ndarray,        # (B, Hkv)
    scale: float,
    out_dtype,
) -> jnp.ndarray:
    """Shared masked-softmax core of the width-sliced einsum fallbacks.

    Operates on *gathered* table blocks only — O(B·Hkv·W·bs) FLOPs and
    bytes instead of the full-cache O(B·Hkv·S).  Table entries at ranks
    ≥ ``counts`` are repeat-last padding (the kernel's ``w < counts``
    guard); the ``live`` mask kills them here so the padded copies of the
    last block are not double-counted.
    """
    b, hkv, w, bs, dv = vg.shape
    live = (jnp.arange(w, dtype=jnp.int32)[None, None, :]
            < counts[..., None])                       # (B, Hkv, W)
    logits = jnp.einsum("bkgd,bkwsd->bkgws", qg, kg,
                        preferred_element_type=jnp.float32) * scale
    ok = (jnp.moveaxis(keep_g, -1, 2)[..., None]       # (B, Hkv, G, W, 1)
          & valid_g[:, :, None]                        # (B, Hkv, 1, W, bs)
          & live[:, :, None, :, None])
    logits = jnp.where(ok, logits, NEG_INF)
    flat = logits.reshape(b, hkv, -1, w * bs)
    ok_f = ok.reshape(b, hkv, -1, w * bs)
    m = jnp.max(flat, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.where(ok_f, jnp.exp(flat - m), 0.0)
    denom = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    pv = jnp.asarray(p / denom, vg.dtype).reshape(b, hkv, -1, w, bs)
    out = jnp.einsum("bkgws,bkwsd->bkgd", pv, vg,
                     preferred_element_type=jnp.float32)
    return jnp.asarray(out, out_dtype).reshape(b, hkv * out.shape[2], dv)


def decode_plan_einsum_sliced(
    q: jnp.ndarray,             # (B, H, D)
    cache_k: jnp.ndarray,       # (B, Hkv, S, D)
    cache_v: jnp.ndarray,       # (B, Hkv, S, Dv)
    plan: DecodePlan,           # one layer's slice
    valid: jnp.ndarray,         # (B, S) bool
) -> jnp.ndarray:
    """Width-sliced einsum fallback: gather only the plan's W table blocks
    and contract those, so a narrow plan (W < NB, e.g. after a pattern
    refresh) does proportionally less work on non-TPU backends — the
    einsum analogue of the kernel's block skipping.  Padding-safe via the
    ``counts`` guard; same masked-softmax math as :func:`decode_plan_
    einsum` but a different reduction *order* (per-block gather), so it is
    dispatched only for W < NB plans — full-width plans keep the bitwise
    legacy path.
    """
    b, h, d = q.shape
    _, hkv, s, dv = cache_v.shape
    nb = plan.keep_heads.shape[2]
    bs = s // nb
    idx = plan.indices                                 # (B, Hkv, W)
    exp = idx[..., None, None]
    kg = jnp.take_along_axis(cache_k.reshape(b, hkv, nb, bs, d), exp, axis=2)
    vg = jnp.take_along_axis(cache_v.reshape(b, hkv, nb, bs, dv), exp, axis=2)
    keep_g = jnp.take_along_axis(plan.keep_heads, idx[..., None], axis=2)
    valid_b = jnp.broadcast_to(valid.reshape(b, 1, nb, bs), (b, hkv, nb, bs))
    valid_g = jnp.take_along_axis(valid_b, idx[..., None], axis=2)
    return _plan_einsum_sliced(q.reshape(b, hkv, h // hkv, d), kg, vg,
                               keep_g, valid_g, plan.counts,
                               1.0 / (d ** 0.5), q.dtype)


def flash_decode_plan(
    q: jnp.ndarray,             # (B, H, D)
    cache_k: jnp.ndarray,       # (B, Hkv, S, D)
    cache_v: jnp.ndarray,       # (B, Hkv, S, Dv)
    plan: DecodePlan,           # one layer's slice — (B, …) leaves
    valid: jnp.ndarray,         # (B, S) bool
    *,
    impl: str = "auto",
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Backend-auto sparse decode over a prebuilt plan (see
    :func:`resolve_decode_impl`).  Returns (B, H, Dv).

    The einsum fallback dispatches on the plan's static width: W == NB
    (every plan the scheduler builds without refresh) takes the legacy
    full-cache contraction bitwise-unchanged; W < NB (refresh-narrowed
    plans) takes :func:`decode_plan_einsum_sliced`, which only touches
    the W gathered blocks.
    """
    impl = resolve_decode_impl(impl)
    if impl == "kernel":
        return flash_decode_sparse_batched(
            q, cache_k, cache_v, plan.indices, plan.counts, plan.keep_heads,
            valid, interpret=interpret)
    if plan.indices.shape[-1] < plan.keep_heads.shape[-2]:
        return decode_plan_einsum_sliced(q, cache_k, cache_v, plan, valid)
    return decode_plan_einsum(q, cache_k, cache_v, plan.keep_heads, valid)


# --------------------------------------------------------------------------
# Block-paged variants: K/V live in a shared page pool, one page per block
# --------------------------------------------------------------------------

def gather_pages(pool: jnp.ndarray, page_table: jnp.ndarray) -> jnp.ndarray:
    """Materialize a contiguous per-slot cache view from a page pool.

    pool ``(P, Hkv, ps, D)``, page_table ``(B, NB)`` int32 →
    ``(B, Hkv, NB·ps, D)``.  A pure gather: the returned values at every
    resident position are bitwise the page contents, so any contiguous
    attention path run on the gathered view matches the paged kernels
    exactly.
    """
    b, nb = page_table.shape
    _, hkv, ps, d = pool.shape
    g = jnp.take(pool, page_table.reshape(-1), axis=0)   # (B·NB, Hkv, ps, D)
    g = g.reshape(b, nb, hkv, ps, d)
    return jnp.moveaxis(g, 1, 2).reshape(b, hkv, nb * ps, d)


def _paged_kernel(pt_ref, idx_ref, cnt_ref,
                  q_ref, k_ref, v_ref, keep_ref, val_ref,
                  out_ref, acc_ref, m_ref, l_ref,
                  *, scale: float, w_steps: int):
    # pt_ref is consumed by the K/V BlockSpec index maps only — the kernel
    # body is the contiguous batched kernel verbatim.
    del pt_ref
    _batched_kernel(idx_ref, cnt_ref, q_ref, k_ref, v_ref, keep_ref,
                    val_ref, out_ref, acc_ref, m_ref, l_ref,
                    scale=scale, w_steps=w_steps)


def flash_decode_sparse_batched_paged(
    q: jnp.ndarray,             # (B, H, D) one token per slot
    pool_k: jnp.ndarray,        # (P, Hkv, ps, D) shared page pool
    pool_v: jnp.ndarray,        # (P, Hkv, ps, Dv)
    page_table: jnp.ndarray,    # (B, NB) int32 logical block → page id
    indices: jnp.ndarray,       # (B, Hkv, W) int32 logical block table
    counts: jnp.ndarray,        # (B, Hkv) int32
    keep_heads: jnp.ndarray,    # (B, Hkv, NB, G) bool
    valid: jnp.ndarray,         # (B, NB·ps) bool logical slot validity
    *,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """:func:`flash_decode_sparse_batched` over a block-paged KV cache.

    The DecodePlan stays logical — block ids, keep bits and validity are
    indexed exactly as in the contiguous kernel — and only the K/V DMA
    address is translated through the scalar-prefetched page table:
    ``page = page_table[b, indices[b, h, w]]``.  Since
    ``page_size == block_size``, a sparse block table row *is* a walk of
    the slot's resident pages, and the online-softmax body is shared with
    the contiguous kernel, so outputs are bitwise-identical to running it
    on the gathered contiguous view.

    Returns (B, H, Dv).
    """
    b, h, d = q.shape
    _, hkv, ps, dv = pool_v.shape
    g = h // hkv
    w_steps = indices.shape[-1]
    scale = 1.0 / (d ** 0.5)
    qg = q.reshape(b, hkv, g, d)

    kernel = functools.partial(_paged_kernel, scale=scale, w_steps=w_steps)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, hkv, w_steps),
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda b_, h_, w, pt, idx, cnt: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, ps, d),
                         lambda b_, h_, w, pt, idx, cnt:
                         (pt[b_, idx[b_, h_, w]], h_, 0, 0)),
            pl.BlockSpec((1, 1, ps, dv),
                         lambda b_, h_, w, pt, idx, cnt:
                         (pt[b_, idx[b_, h_, w]], h_, 0, 0)),
            pl.BlockSpec((1, 1, 1, g),
                         lambda b_, h_, w, pt, idx, cnt:
                         (b_, h_, idx[b_, h_, w], 0)),
            pl.BlockSpec((1, ps),
                         lambda b_, h_, w, pt, idx, cnt:
                         (b_, idx[b_, h_, w])),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dv),
                               lambda b_, h_, w, pt, idx, cnt:
                               (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, dv), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
    )
    # The pool's K/V tiles carry their page axis as a singleton block dim,
    # so k_ref/v_ref arrive as (1, 1, ps, D) — same shape the contiguous
    # kernel sees.
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, dv), q.dtype),
        interpret=_auto_interpret(interpret),
    )(page_table, indices, counts, qg, pool_k, pool_v, keep_heads, valid)
    return out.reshape(b, h, dv)


def decode_plan_einsum_paged(
    q: jnp.ndarray,
    pool_k: jnp.ndarray,        # (P, Hkv, ps, D)
    pool_v: jnp.ndarray,
    page_table: jnp.ndarray,    # (B, NB)
    keep_heads: jnp.ndarray,
    valid: jnp.ndarray,
) -> jnp.ndarray:
    """Einsum fallback for the paged cache: gather the resident pages into
    the contiguous view (``jnp.take``) and reuse the contiguous fallback —
    bitwise-equal by construction."""
    return decode_plan_einsum(q, gather_pages(pool_k, page_table),
                              gather_pages(pool_v, page_table),
                              keep_heads, valid)


def decode_plan_einsum_sliced_paged(
    q: jnp.ndarray,             # (B, H, D)
    pool_k: jnp.ndarray,        # (P, Hkv, ps, D)
    pool_v: jnp.ndarray,        # (P, Hkv, ps, Dv)
    page_table: jnp.ndarray,    # (B, NB) int32
    plan: DecodePlan,
    valid: jnp.ndarray,         # (B, NB·ps) bool
) -> jnp.ndarray:
    """:func:`decode_plan_einsum_sliced` over the block-paged pool: the
    logical block table is translated through the page table first
    (``page = page_table[b, indices[b, h, w]]``), then only those W pages
    are gathered from the pool — the full-cache ``gather_pages``
    materialization is skipped entirely, which is where the paged
    fallback's traffic actually goes.
    """
    b, h, d = q.shape
    _, hkv, ps, dv = pool_v.shape
    nb = page_table.shape[1]
    idx = plan.indices                                 # (B, Hkv, W)
    pages = jnp.take_along_axis(
        jnp.broadcast_to(page_table[:, None, :], (b, hkv, nb)), idx, axis=-1)

    def _per_head(pool_h, pages_h):                    # (P, ps, D), (B, W)
        return jnp.take(pool_h, pages_h, axis=0)       # (B, W, ps, D)

    gather = jax.vmap(_per_head, in_axes=(1, 1), out_axes=1)
    kg = gather(pool_k, pages)                         # (B, Hkv, W, ps, D)
    vg = gather(pool_v, pages)
    keep_g = jnp.take_along_axis(plan.keep_heads, idx[..., None], axis=2)
    valid_b = jnp.broadcast_to(valid.reshape(b, 1, nb, ps), (b, hkv, nb, ps))
    valid_g = jnp.take_along_axis(valid_b, idx[..., None], axis=2)
    return _plan_einsum_sliced(q.reshape(b, hkv, h // hkv, d), kg, vg,
                               keep_g, valid_g, plan.counts,
                               1.0 / (d ** 0.5), q.dtype)


def flash_decode_plan_paged(
    q: jnp.ndarray,
    pool_k: jnp.ndarray,
    pool_v: jnp.ndarray,
    page_table: jnp.ndarray,
    plan: DecodePlan,           # one layer's slice, logical block ids
    valid: jnp.ndarray,         # (B, NB·ps)
    *,
    impl: str = "auto",
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Backend-auto sparse decode over a block-paged cache.

    Same width dispatch as :func:`flash_decode_plan`: full-width plans
    (W == NB) keep the legacy gather-then-contract fallback bitwise;
    refresh-narrowed plans (W < NB) gather only their table pages.
    """
    impl = resolve_decode_impl(impl)
    if impl == "kernel":
        return flash_decode_sparse_batched_paged(
            q, pool_k, pool_v, page_table, plan.indices, plan.counts,
            plan.keep_heads, valid, interpret=interpret)
    if plan.indices.shape[-1] < plan.keep_heads.shape[-2]:
        return decode_plan_einsum_sliced_paged(q, pool_k, pool_v,
                                               page_table, plan, valid)
    return decode_plan_einsum_paged(q, pool_k, pool_v, page_table,
                                    plan.keep_heads, valid)
