"""Chunked exact attention in pure JAX (flash-style lax.scan over q blocks).

This is the O(N)-memory attention used (a) as the differentiable training
attention, (b) as the numerical fallback of the sparse execution path
(:func:`repro.kernels.sparse_attention_fn`) on shapes the Pallas kernel
cannot take — non-block-aligned sequences, too-few blocks — and (c) as the
large-N variant of the block-sparse oracle.  Semantics match
:mod:`repro.kernels.ref` exactly; tests assert allclose between the two and
against the Pallas kernel.

Accepts an optional block mask: masked blocks contribute nothing to the
softmax and carry −inf in the emitted Ã, token-for-token identical to the
Pallas block-sparse kernel — but as a *dense* path it issues the FLOPs for
every block.  It is the oracle and the fallback, not the hot path: the
default SharePrefill backend is ``repro.kernels.sparse_attention_fn``, whose
Pallas kernel skips inactive blocks (compute *and* DMA) on TPU.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = float("-inf")

# below this block size, the divisor fallback pads instead of shrinking
_MIN_FALLBACK_BLOCK = 16


def largest_divisor_block(n: int, nkv: int, block_size: int) -> int:
    """Largest common divisor of ``n`` and ``nkv`` that is ≤ ``block_size``.

    The naive ``while n % bs: bs -= 1`` fallback degrades to ``bs == 1`` for
    prime-ish sequence lengths — an O(N)-iteration scan of 1-row blocks.
    Searching the divisors of gcd(n, nkv) from ``block_size`` down finds the
    best block in O(block_size) host-side work at trace time.
    """
    g = math.gcd(n, nkv)
    for bs in range(min(block_size, g), 0, -1):
        if g % bs == 0:
            return bs
    return 1


def chunked_attention(
    q: jnp.ndarray,                     # (B, H, N, Dqk)
    k: jnp.ndarray,                     # (B, H, Nkv, Dqk)  (kv pre-expanded)
    v: jnp.ndarray,                     # (B, H, Nkv, Dv)
    *,
    block_size: int = 128,
    causal: bool = True,
    block_mask: Optional[jnp.ndarray] = None,   # (B, H, NBq, NBkv) bool
    window: int = 0,                    # sliding window in tokens (0 = full)
    sink: int = 0,                      # always-visible prefix tokens
    collect_stats: bool = False,
    q_offset: Optional[int] = None,     # global position of q row 0 (tokens)
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Exact attention, scanned over query blocks.

    Returns ``(out (B,H,N,Dv), a_tilde (B,H,NBq,NBkv) | None)``.

    ``q_offset`` places the queries inside the key timeline: q row ``i`` is
    global position ``q_offset + i``.  The default ``Nkv − N`` keeps the
    legacy suffix alignment (one-shot prefill, decode tails); chunked
    prefill passes the chunk's token cursor so an interior Q-chunk sees the
    causal/window bounds of its own rows.

    When no block mask is given and no usable divisor of ``N`` exists (see
    :func:`largest_divisor_block`), the inputs are zero-padded to the
    requested block.  ``out`` is sliced back to ``N``; ``a_tilde`` then
    follows the *padded* block grid — padded queries/keys are excluded from
    every block mean (rows/blocks touching only padding are −inf), but
    callers that need an exact N-aligned grid should pass block-aligned
    inputs.
    """
    b, h, n, d = q.shape
    nkv = k.shape[2]
    n_orig, nkv_orig = n, nkv
    pad_q = pad_kv = 0
    if block_mask is None:
        # no mask to respect — shrink to the largest divisor, or, when only
        # a degenerate block divides (prime-ish N), pad to the requested
        # block instead of scanning 1-row blocks
        best = largest_divisor_block(n, nkv, block_size)
        if best >= min(block_size, _MIN_FALLBACK_BLOCK):
            block_size = best
        else:
            pad_q = -n % block_size
            pad_kv = -nkv % block_size
            if pad_q or pad_kv:
                q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
                k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
                v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
                n, nkv = n + pad_q, nkv + pad_kv
    nbq = n // block_size
    nbkv = nkv // block_size
    scale = 1.0 / (d ** 0.5)
    q32 = jnp.asarray(q, jnp.float32)
    k32 = jnp.asarray(k, jnp.float32)
    v32 = jnp.asarray(v, jnp.float32)
    # query i is global position i+offset (original, pre-pad alignment)
    offset = (nkv_orig - n_orig) if q_offset is None else int(q_offset)

    kpos = jnp.arange(nkv)

    def body(carry, i):
        del carry
        qb = jax.lax.dynamic_slice_in_dim(q32, i * block_size, block_size, 2)
        logits = jnp.einsum("bhqd,bhkd->bhqk", qb, k32) * scale
        qidx = i * block_size + jnp.arange(block_size)
        qpos = qidx + offset
        valid = jnp.ones((block_size, nkv), dtype=bool)
        if pad_kv:
            valid &= kpos[None, :] < nkv_orig
        if pad_q:
            # padded query rows must not leak into collect_stats block means
            valid &= qidx[:, None] < n_orig
        if causal:
            valid &= kpos[None, :] <= qpos[:, None]
        if window > 0:
            in_win = (qpos[:, None] - kpos[None, :]) < window
            valid &= in_win | (kpos[None, :] < sink)
        if block_mask is not None:
            row = jax.lax.dynamic_slice_in_dim(block_mask, i, 1, 2)[:, :, 0]
            tokrow = jnp.repeat(row, block_size, axis=-1)     # (B,H,Nkv)
            valid = valid[None, None] & tokrow[:, :, None, :]
        else:
            valid = jnp.broadcast_to(valid[None, None],
                                     (b, h, block_size, nkv))
        masked = jnp.where(valid, logits, NEG_INF)
        m = jnp.max(masked, axis=-1, keepdims=True)
        m = jnp.where(jnp.isfinite(m), m, 0.0)
        p = jnp.where(valid, jnp.exp(masked - m), 0.0)
        denom = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
        ob = jnp.einsum("bhqk,bhkd->bhqd", p / denom, v32)

        if collect_stats:
            lg = logits.reshape(b, h, block_size, nbkv, block_size)
            vd = valid.reshape(b, h, block_size, nbkv, block_size)
            cnt = jnp.sum(vd, axis=(2, 4))
            s = jnp.sum(jnp.where(vd, lg, 0.0), axis=(2, 4))
            stats = jnp.where(cnt > 0, s / jnp.maximum(cnt, 1), NEG_INF)
        else:
            stats = jnp.zeros((b, h, 0), jnp.float32)
        return None, (jnp.asarray(ob, q.dtype), stats)

    _, (blocks, stats) = jax.lax.scan(body, None, jnp.arange(nbq))
    out = jnp.moveaxis(blocks, 0, 2).reshape(b, h, n, -1)
    if pad_q:
        out = out[:, :, :n_orig]
    if collect_stats:
        a_tilde = jnp.moveaxis(stats, 0, 2)                   # (B,H,NBq,NBkv)
        return out, a_tilde
    return out, None


def chunked_attention_fn(*, block_size: int, causal: bool = True):
    """AttentionFn adapter for repro.core.share_attention (single sample,
    (H, N, D) q and un-expanded (Hkv, N, D) k/v, always collects Ã)."""
    def fn(q, k, v, masks):
        from repro.kernels.ops import expand_kv
        k, v = expand_kv(k, v, q.shape[0])
        out, a_tilde = chunked_attention(
            q[None], k[None], v[None], block_size=block_size,
            causal=causal, block_mask=masks[None], collect_stats=True)
        return out[0], a_tilde[0]
    return fn
