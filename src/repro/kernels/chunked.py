"""Chunked exact attention in pure JAX (flash-style lax.scan over q blocks).

This is the O(N)-memory attention used (a) as the differentiable training
attention, (b) as the dry-run lowering path where XLA:CPU cannot express
data-dependent block skipping (DESIGN.md §3), and (c) as the large-N variant
of the block-sparse oracle.  Semantics match :mod:`repro.kernels.ref`
exactly; tests assert allclose between the two and against the Pallas kernel.

Accepts an optional block mask: masked blocks contribute nothing to the
softmax and carry −inf in the emitted Ã (matching the sparse kernel), but the
FLOPs are still issued — on TPU the Pallas kernel is the one that skips.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = float("-inf")


def chunked_attention(
    q: jnp.ndarray,                     # (B, H, N, Dqk)
    k: jnp.ndarray,                     # (B, H, Nkv, Dqk)  (kv pre-expanded)
    v: jnp.ndarray,                     # (B, H, Nkv, Dv)
    *,
    block_size: int = 128,
    causal: bool = True,
    block_mask: Optional[jnp.ndarray] = None,   # (B, H, NBq, NBkv) bool
    window: int = 0,                    # sliding window in tokens (0 = full)
    sink: int = 0,                      # always-visible prefix tokens
    collect_stats: bool = False,
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """Exact attention, scanned over query blocks.

    Returns ``(out (B,H,N,Dv), a_tilde (B,H,NBq,NBkv) | None)``.
    """
    b, h, n, d = q.shape
    nkv = k.shape[2]
    if block_mask is None:
        # no mask to respect — free to shrink the block until it divides
        while n % block_size or nkv % block_size:
            block_size -= 1
    nbq = n // block_size
    nbkv = nkv // block_size
    scale = 1.0 / (d ** 0.5)
    q32 = jnp.asarray(q, jnp.float32)
    k32 = jnp.asarray(k, jnp.float32)
    v32 = jnp.asarray(v, jnp.float32)
    offset = nkv - n                      # query i is global position i+offset

    kpos = jnp.arange(nkv)

    def body(carry, i):
        del carry
        qb = jax.lax.dynamic_slice_in_dim(q32, i * block_size, block_size, 2)
        logits = jnp.einsum("bhqd,bhkd->bhqk", qb, k32) * scale
        qpos = i * block_size + jnp.arange(block_size) + offset
        valid = jnp.ones((block_size, nkv), dtype=bool)
        if causal:
            valid &= kpos[None, :] <= qpos[:, None]
        if window > 0:
            in_win = (qpos[:, None] - kpos[None, :]) < window
            valid &= in_win | (kpos[None, :] < sink)
        if block_mask is not None:
            row = jax.lax.dynamic_slice_in_dim(block_mask, i, 1, 2)[:, :, 0]
            tokrow = jnp.repeat(row, block_size, axis=-1)     # (B,H,Nkv)
            valid = valid[None, None] & tokrow[:, :, None, :]
        else:
            valid = jnp.broadcast_to(valid[None, None],
                                     (b, h, block_size, nkv))
        masked = jnp.where(valid, logits, NEG_INF)
        m = jnp.max(masked, axis=-1, keepdims=True)
        m = jnp.where(jnp.isfinite(m), m, 0.0)
        p = jnp.where(valid, jnp.exp(masked - m), 0.0)
        denom = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
        ob = jnp.einsum("bhqk,bhkd->bhqd", p / denom, v32)

        if collect_stats:
            lg = logits.reshape(b, h, block_size, nbkv, block_size)
            vd = valid.reshape(b, h, block_size, nbkv, block_size)
            cnt = jnp.sum(vd, axis=(2, 4))
            s = jnp.sum(jnp.where(vd, lg, 0.0), axis=(2, 4))
            stats = jnp.where(cnt > 0, s / jnp.maximum(cnt, 1), NEG_INF)
        else:
            stats = jnp.zeros((b, h, 0), jnp.float32)
        return None, (jnp.asarray(ob, q.dtype), stats)

    _, (blocks, stats) = jax.lax.scan(body, None, jnp.arange(nbq))
    out = jnp.moveaxis(blocks, 0, 2).reshape(b, h, n, -1)
    if collect_stats:
        a_tilde = jnp.moveaxis(stats, 0, 2)                   # (B,H,NBq,NBkv)
        return out, a_tilde
    return out, None


def chunked_attention_fn(*, block_size: int):
    """AttentionFn adapter for repro.core.share_attention (single sample,
    (H, N, D) operands, always collects Ã)."""
    def fn(q, kx, vx, masks):
        out, a_tilde = chunked_attention(
            q[None], kx[None], vx[None], block_size=block_size,
            causal=True, block_mask=masks[None], collect_stats=True)
        return out[0], a_tilde[0]
    return fn
