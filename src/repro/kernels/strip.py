"""Pallas strip-score kernel for the Algorithm-3 estimation pass.

SharePrefill estimates each head's block pattern from the *last query block
strip* — softmax(Q̂ Kᵀ/√d) for Q̂ = Q[-block_size:].  The pure-jnp
:func:`strip_scores` oracle materializes the full (block_size, N) logits,
the causal ``where`` mask, and the softmax temporaries in HBM before
producing the strip.  The Pallas version streams K through VMEM in
``block_size`` tiles with a flash-style online-softmax scan:

  * pass 1 (``_strip_ml_kernel``) — FA-2 running max / running denominator
    over kv tiles; only the final per-row (m, l) leaves the kernel;
  * pass 2 (``_strip_norm_kernel``) — re-scores each tile and writes the
    exactly-normalized probabilities ``exp(s − m)/l`` straight to the output,
    so the strip is the *only* (block_size, N) array that ever touches HBM.

Both kernels are GQA-native: query head ``h`` reads kv head ``h // group``
through the BlockSpec index_map, so grouped K is never repeated.

Causality comes cheap: strip rows are the globally-last queries, so every kv
tile except the final one is fully visible — only tile ``NB−1`` is masked.

``compute_strips`` is the dispatcher used by the orchestration: the pure-jnp
oracle on CPU hosts (where Pallas only interprets), the kernel on TPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


# --------------------------------------------------------------------------
# Pure-jnp oracle (also the CPU execution path)
# --------------------------------------------------------------------------

def strip_scores(q: jnp.ndarray, k: jnp.ndarray,
                 block_size: int) -> jnp.ndarray:
    """softmax(Q̂ Kᵀ/√d) for the last query block; (block_size, N)."""
    n, d = k.shape
    q_hat = q[-block_size:, :]
    logits = (q_hat @ k.T) / jnp.sqrt(jnp.asarray(d, q.dtype))
    # causal: row r of the strip is global query N - block_size + r
    rows = jnp.arange(block_size) + (n - block_size)
    cols = jnp.arange(n)
    logits = jnp.where(cols[None, :] <= rows[:, None], logits, -jnp.inf)
    logits = jnp.asarray(logits, jnp.float32)
    p = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    return p / jnp.sum(p, axis=-1, keepdims=True)


# --------------------------------------------------------------------------
# Pallas kernels
# --------------------------------------------------------------------------

def _tile_logits(q_ref, k_ref, j, *, block_size, n, scale):
    """(bs, bs) scaled QK logits of kv tile j, −inf outside causality."""
    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale
    q_pos = (n - block_size) + jax.lax.broadcasted_iota(
        jnp.int32, (block_size, block_size), 0)
    k_pos = j * block_size + jax.lax.broadcasted_iota(
        jnp.int32, (block_size, block_size), 1)
    valid = k_pos <= q_pos
    return jnp.where(valid, s, NEG_INF), valid


def _strip_ml_kernel(q_ref, k_ref, m_out, l_out, m_ref, l_ref,
                     *, block_size: int, n: int, scale: float):
    j = pl.program_id(1)
    nb = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    s, valid = _tile_logits(q_ref, k_ref, j, block_size=block_size, n=n,
                            scale=scale)
    m_prev = m_ref[...]                              # (bs, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_new), 0.0)
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    m_ref[...] = m_new

    @pl.when(j == nb - 1)
    def _finalize():
        m_out[0, :] = m_ref[...][:, 0]
        l_out[0, :] = l_ref[...][:, 0]


def _strip_norm_kernel(q_ref, k_ref, m_ref, l_ref, out_ref,
                       *, block_size: int, n: int, scale: float):
    j = pl.program_id(1)
    s, valid = _tile_logits(q_ref, k_ref, j, block_size=block_size, n=n,
                            scale=scale)
    m = m_ref[0][:, None]                            # (bs, 1)
    l = jnp.maximum(l_ref[0][:, None], 1e-30)
    out_ref[0] = jnp.where(valid, jnp.exp(s - m), 0.0) / l


def strip_scores_pallas(
    q: jnp.ndarray,             # (H, N, D)
    k: jnp.ndarray,             # (Hkv, N, D)
    *,
    block_size: int,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused last-query-block strips for all heads; (H, block_size, N) f32.

    ``q`` may be shorter than ``k`` along the sequence axis (e.g. just the
    captured last-block query window during a decode-time refresh) — the
    key length ``N``, and with it the causal row offsets, always come from
    ``k``; only ``q``'s last ``block_size`` rows are read.
    """
    h, _, d = q.shape
    h_kv, n = k.shape[:2]
    group = h // h_kv
    nb = n // block_size
    scale = 1.0 / (d ** 0.5)
    q_hat = q[:, q.shape[1] - block_size:, :]

    q_spec = pl.BlockSpec((1, block_size, d), lambda hh, jj: (hh, 0, 0))
    k_spec = pl.BlockSpec((1, block_size, d),
                          lambda hh, jj: (hh // group, jj, 0))

    ml_kernel = functools.partial(_strip_ml_kernel, block_size=block_size,
                                  n=n, scale=scale)
    m, l = pl.pallas_call(
        ml_kernel,
        grid=(h, nb),
        in_specs=[q_spec, k_spec],
        out_specs=[
            pl.BlockSpec((1, block_size), lambda hh, jj: (hh, 0)),
            pl.BlockSpec((1, block_size), lambda hh, jj: (hh, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, block_size), jnp.float32),
            jax.ShapeDtypeStruct((h, block_size), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_size, 1), jnp.float32),
            pltpu.VMEM((block_size, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q_hat, k)

    norm_kernel = functools.partial(_strip_norm_kernel, block_size=block_size,
                                    n=n, scale=scale)
    strip = pl.pallas_call(
        norm_kernel,
        grid=(h, nb),
        in_specs=[
            q_spec, k_spec,
            pl.BlockSpec((1, block_size), lambda hh, jj: (hh, 0)),
            pl.BlockSpec((1, block_size), lambda hh, jj: (hh, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_size, block_size),
                               lambda hh, jj: (hh, 0, jj)),
        out_shape=jax.ShapeDtypeStruct((h, block_size, n), jnp.float32),
        interpret=interpret,
    )(q_hat, k, m, l)
    return strip


# --------------------------------------------------------------------------
# Dispatcher
# --------------------------------------------------------------------------

def compute_strips(
    q: jnp.ndarray,             # (H, N, D)
    k: jnp.ndarray,             # (Hkv, N, D)
    *,
    block_size: int,
    impl: str = "auto",         # auto | pallas | jnp
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """GQA-native strips for all query heads, (H, block_size, N) f32.

    ``auto`` runs the Pallas kernel compiled on TPU and the pure-jnp oracle
    elsewhere (interpret mode is a validation tool, not an execution path).
    Neither path repeats K across the GQA group.
    """
    on_tpu = jax.default_backend() == "tpu"
    if impl == "auto":
        impl = "pallas" if on_tpu else "jnp"
    if impl == "pallas" and (k.shape[1] % block_size
                             or q.shape[1] < block_size):
        # the kernel grid covers whole kv tiles only — a ragged tail would
        # silently drop keys from the softmax denominator
        impl = "jnp"
    if impl == "pallas":
        it = interpret if interpret is not None else not on_tpu
        return strip_scores_pallas(q, k, block_size=block_size, interpret=it)
    if impl != "jnp":
        raise ValueError(f"unknown strip impl {impl!r}")
    from repro.kernels.ops import gqa_head_vmap
    return gqa_head_vmap(
        lambda qh, kh: strip_scores(qh, kh, block_size), q, k)


def compute_strips_paged(
    q_hat: jnp.ndarray,         # (H, block_size, D) recent-query window
    pool_k: jnp.ndarray,        # (P, Hkv, ps, D) shared page pool
    page_table: jnp.ndarray,    # (NB,) int32 one slot's logical→page map
    *,
    block_size: int,
    num_blocks: int,            # static: live (block-aligned) block count
    impl: str = "auto",
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """:func:`compute_strips` over one slot's live paged KV.

    The decode-time re-estimation entry point (``serving/refresh.py``):
    ``q_hat`` is the slot's captured last-``block_size`` decode queries
    (positions ``[n − block_size, n)`` for ``n = num_blocks ·
    block_size``), and K is gathered from the page pool through the
    slot's page-table prefix — a pure gather (bitwise page contents, same
    argument as :func:`repro.kernels.decode_attn.gather_pages`), so the
    strip equals running the contiguous kernel on the slot's cache.  The
    strip rows being the globally-last queries is exactly the kernels'
    causal assumption, which is why refresh only fires at block-aligned
    positions.

    Returns (H, block_size, num_blocks · ps) f32.
    """
    _, hkv, ps, d = pool_k.shape
    kg = jnp.take(pool_k, page_table[:num_blocks], axis=0)
    k = jnp.moveaxis(kg, 0, 1).reshape(hkv, num_blocks * ps, d)
    return compute_strips(q_hat, k, block_size=block_size, impl=impl,
                          interpret=interpret)
