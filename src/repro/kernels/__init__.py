"""Pallas TPU kernels for the paper's compute hot-spots plus pure-jnp oracles.

  block_sparse_attn.py  pl.pallas_call + BlockSpec splash-style kernel
  strip.py              flash-style strip-score kernel (Algorithm-3 pass)
  decode_attn.py        flash-decode kernels + DecodePlan block-table
                        contract (batched block-skipping serving path)
  indices.py            mask ⇄ (indices, counts) staging + Ã scatter
  ops.py                jit'd wrappers (index staging, Ã scatter)
  ref.py                pure-jnp oracles the kernels are validated against

``sparse_attention_fn`` is the default SharePrefill attention backend: the
block-skipping Pallas kernel, compiled on TPU / interpreted elsewhere, with
a dense-chunked fallback on shapes the kernel cannot take.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.decode_attn import (
    DecodePlan,
    flash_decode,
    flash_decode_plan,
    flash_decode_sparse,
    flash_decode_sparse_batched,
    resolve_decode_impl,
)
from repro.kernels.indices import (
    build_block_tables,
    cap_block_mask,
    compact_block_mask,
    scatter_block_stats,
)
from repro.kernels.ops import (
    block_sparse_attention,
    expand_kv,
    gqa_head_vmap,
    make_attention_fn,
)
from repro.kernels.ref import (
    block_sparse_attention_ref,
    decode_attention_ref,
    dense_attention_ref,
)
from repro.kernels.strip import compute_strips, strip_scores_pallas


def sparse_attention_fn(*, block_size: int, causal: bool = True,
                        width: Optional[int] = None,
                        interpret: Optional[bool] = None):
    """Bind the sparse execution path as an AttentionFn.

    The returned callable satisfies the :data:`repro.core.share_attention.
    AttentionFn` protocol — ``(q (H,N,D), k (Hkv,N,D), v (Hkv,N,Dv),
    masks (H,NB,NB)) -> (out (H,N,Dv), Ã (H,NB,NB))`` — and is GQA-native:
    grouped K/V are consumed as-is, the kernel's BlockSpec index_map resolves
    ``h // group``.

    ``interpret=None`` auto-selects by backend: compiled on TPU, interpret
    mode elsewhere (the CPU container runs the same kernel through the Pallas
    interpreter, so the execution path exercised in tests is the one deployed
    on hardware).

    Mask-grid contract: the ``(H, NB, NB)`` masks must tile the sequence —
    each block row governs exactly ``N / NB`` tokens.  When that granularity
    is ``block_size`` the Pallas kernel runs; any other tiling granularity
    (e.g. a mask built at a finer block size) falls back to the dense
    chunked path at ``N // NB`` tokens per block.  A mask whose grid does
    not divide ``N`` at all is a caller error and raises ``ValueError`` —
    the backend never stretches mask bits over token ranges they were not
    estimated for.  ``width`` forwards the static per-row block budget W
    (see :mod:`repro.kernels.indices`) on both paths.
    """
    from repro.kernels.chunked import chunked_attention_fn

    it = interpret if interpret is not None \
        else jax.default_backend() != "tpu"

    def fn(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
           masks: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        n = q.shape[1]
        nb = masks.shape[-1]
        if nb * block_size == n:
            return block_sparse_attention(
                q, k, v, masks, block_size=block_size, causal=causal,
                impl="kernel", interpret=it, width=width)
        # chunked fallback: applicable() failed upstream or the mask was
        # built at a different granularity — run dense, same semantics
        if nb == 0 or n % nb:
            raise ValueError(
                f"mask grid {nb} does not tile sequence length {n}")
        if width is not None:
            # apply the same W-cap truncation the kernel path would
            masks = cap_block_mask(masks, width)
        return chunked_attention_fn(block_size=n // nb,
                                    causal=causal)(q, k, v, masks)

    return fn


__all__ = [
    "DecodePlan", "block_sparse_attention", "build_block_tables",
    "cap_block_mask", "compact_block_mask", "compute_strips", "expand_kv",
    "flash_decode", "flash_decode_plan", "flash_decode_sparse",
    "flash_decode_sparse_batched", "gqa_head_vmap", "make_attention_fn",
    "resolve_decode_impl", "scatter_block_stats", "sparse_attention_fn",
    "strip_scores_pallas", "block_sparse_attention_ref",
    "decode_attention_ref", "dense_attention_ref",
]
