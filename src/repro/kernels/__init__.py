"""Pallas TPU kernels for the paper's compute hot-spots plus pure-jnp oracles.

  block_sparse_attn.py  pl.pallas_call + BlockSpec splash-style kernel
  strip.py              flash-style strip-score kernel (Algorithm-3 pass)
  decode_attn.py        flash-decode kernels + DecodePlan block-table
                        contract (batched block-skipping serving path)
  indices.py            mask ⇄ (indices, counts) staging + Ã scatter
  ops.py                jit'd wrappers (index staging, Ã scatter)
  ref.py                pure-jnp oracles the kernels are validated against

``batched_sparse_attention_fn`` is the default SharePrefill attention
backend for batched prefill: the batch-native count-aware Pallas kernel
(ragged ``(B, T, H)`` grid, one ``pallas_call`` for the whole batch),
compiled on TPU / interpreted elsewhere, optionally heads-sharded via
``shard_map``.  ``sparse_attention_fn`` is its per-sample counterpart (the
validation oracle path); both fall back to dense-chunked on shapes the
kernel cannot take.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.decode_attn import (
    DecodePlan,
    flash_decode,
    flash_decode_plan,
    flash_decode_sparse,
    flash_decode_sparse_batched,
    resolve_decode_impl,
)
from repro.kernels.block_sparse_attn import (
    block_sparse_attention_batched,
    ragged_grid_steps,
    ragged_schedule,
)
from repro.kernels.indices import (
    build_block_tables,
    cap_block_mask,
    compact_block_mask,
    scatter_block_stats,
    scatter_schedule_stats,
)
from repro.kernels.ops import (
    batched_block_sparse_attention,
    block_sparse_attention,
    expand_kv,
    gqa_head_vmap,
    make_attention_fn,
)
from repro.kernels.ref import (
    block_sparse_attention_ref,
    decode_attention_ref,
    dense_attention_ref,
)
from repro.kernels.strip import compute_strips, strip_scores_pallas


def sparse_attention_fn(*, block_size: int, causal: bool = True,
                        width: Optional[int] = None,
                        interpret: Optional[bool] = None):
    """Bind the sparse execution path as an AttentionFn.

    The returned callable satisfies the :data:`repro.core.share_attention.
    AttentionFn` protocol — ``(q (H,N,D), k (Hkv,N,D), v (Hkv,N,Dv),
    masks (H,NB,NB)) -> (out (H,N,Dv), Ã (H,NB,NB))`` — and is GQA-native:
    grouped K/V are consumed as-is, the kernel's BlockSpec index_map resolves
    ``h // group``.

    ``interpret=None`` auto-selects by backend: compiled on TPU, interpret
    mode elsewhere (the CPU container runs the same kernel through the Pallas
    interpreter, so the execution path exercised in tests is the one deployed
    on hardware).

    Mask-grid contract: the ``(H, NB, NB)`` masks must tile the sequence —
    each block row governs exactly ``N / NB`` tokens.  When that granularity
    is ``block_size`` the Pallas kernel runs; any other tiling granularity
    (e.g. a mask built at a finer block size) falls back to the dense
    chunked path at ``N // NB`` tokens per block.  A mask whose grid does
    not divide ``N`` at all is a caller error and raises ``ValueError`` —
    the backend never stretches mask bits over token ranges they were not
    estimated for.  ``width`` forwards the static per-row block budget W
    (see :mod:`repro.kernels.indices`) on both paths.
    """
    from repro.kernels.chunked import chunked_attention_fn

    it = interpret if interpret is not None \
        else jax.default_backend() != "tpu"

    def fn(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
           masks: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        n = q.shape[1]
        nb = masks.shape[-1]
        if nb * block_size == n:
            return block_sparse_attention(
                q, k, v, masks, block_size=block_size, causal=causal,
                impl="kernel", interpret=it, width=width)
        # chunked fallback: applicable() failed upstream or the mask was
        # built at a different granularity — run dense, same semantics
        if nb == 0 or n % nb:
            raise ValueError(
                f"mask grid {nb} does not tile sequence length {n}")
        if width is not None:
            # apply the same W-cap truncation the kernel path would
            masks = cap_block_mask(masks, width)
        return chunked_attention_fn(block_size=n // nb,
                                    causal=causal)(q, k, v, masks)

    return fn


def batched_sparse_attention_fn(*, block_size: int, causal: bool = True,
                                width: Optional[int] = None,
                                interpret: Optional[bool] = None,
                                mesh=None, shard_axis: str = "model",
                                q_block_offset: Optional[int] = None):
    """Bind the batch-native sparse execution path as a batched AttentionFn.

    The returned callable satisfies the **batched** AttentionFn protocol —
    ``(q (B,H,N,D), k (B,Hkv,N,D), v (B,Hkv,N,Dv), masks (B,H,NB,NB),
    stats_gate=None) -> (out (B,H,N,Dv), Ã (B,H,NB,NB))`` — and is marked
    with ``fn.batched = True`` so orchestration code
    (:func:`repro.core.share_attention.batched_share_prefill_attention_layer`)
    can hoist the kernel call out of its per-sample ``jax.vmap``: one
    ``pallas_call`` over a ``(B, T, H)`` grid instead of B replayed
    single-sample programs.  ``stats_gate`` (B, H) gates the fused Ã stats
    to the heads that consume them (None = all heads).

    ``mesh`` (optional) runs the kernel under ``shard_map`` with the head
    axes sharded over ``shard_axis`` and the splash index tables built *per
    shard* — SMEM stays O(local heads); see
    :func:`repro.distributed.sharding.sharded_batched_block_sparse_attention`.
    When the head counts do not divide the mesh axis the call falls back to
    the single-device path.

    Mask-grid and ``interpret`` contracts match :func:`sparse_attention_fn`;
    the misaligned-granularity fallback runs the dense chunked path per
    sample (a correctness escape hatch, not a production path).

    ``q_block_offset`` binds a rectangular chunk launch: ``masks`` are
    ``(B, H, NBq, NBkv)`` with ``NBq < NBkv`` allowed, q carries only the
    chunk rows and k/v the full prefix, and causal bounds anchor at the
    chunk's first block.  Chunk launches require exact ``block_size``
    alignment on both axes (no dense fallback) and skip the mesh path —
    chunked admission is single-device.
    """
    from repro.kernels.chunked import chunked_attention_fn
    from repro.kernels.indices import cap_block_mask as _cap

    it = interpret if interpret is not None \
        else jax.default_backend() != "tpu"

    def fn(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
           masks: jnp.ndarray, stats_gate: Optional[jnp.ndarray] = None
           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        n = q.shape[2]
        nb = masks.shape[-1]
        if q_block_offset is not None:
            nbq = masks.shape[-2]
            if nbq * block_size != n or nb * block_size != k.shape[2]:
                raise ValueError(
                    f"chunk launch misaligned: mask grid ({nbq}, {nb}) at "
                    f"block {block_size} vs q {n} / kv {k.shape[2]} tokens")
            return batched_block_sparse_attention(
                q, k, v, masks, block_size=block_size, causal=causal,
                interpret=it, width=width, stats_gate=stats_gate,
                q_block_offset=q_block_offset)
        if nb * block_size == n:
            if mesh is not None:
                from repro.distributed.sharding import (
                    head_shard_count,
                    sharded_batched_block_sparse_attention,
                )
                if head_shard_count(mesh, shard_axis, q.shape[1],
                                    k.shape[1]) > 1:
                    return sharded_batched_block_sparse_attention(
                        q, k, v, masks, mesh=mesh, axis=shard_axis,
                        block_size=block_size, causal=causal, width=width,
                        interpret=it, stats_gate=stats_gate)
            return batched_block_sparse_attention(
                q, k, v, masks, block_size=block_size, causal=causal,
                interpret=it, width=width, stats_gate=stats_gate)
        if nb == 0 or n % nb:
            raise ValueError(
                f"mask grid {nb} does not tile sequence length {n}")
        if width is not None:
            masks = _cap(masks, width)
        base = chunked_attention_fn(block_size=n // nb, causal=causal)
        return jax.vmap(base)(q, k, v, masks)

    fn.batched = True
    return fn


__all__ = [
    "DecodePlan", "batched_block_sparse_attention",
    "batched_sparse_attention_fn", "block_sparse_attention",
    "block_sparse_attention_batched", "build_block_tables",
    "cap_block_mask", "compact_block_mask", "compute_strips", "expand_kv",
    "flash_decode", "flash_decode_plan", "flash_decode_sparse",
    "flash_decode_sparse_batched", "gqa_head_vmap", "make_attention_fn",
    "ragged_grid_steps", "ragged_schedule", "resolve_decode_impl",
    "scatter_block_stats", "scatter_schedule_stats", "sparse_attention_fn",
    "strip_scores_pallas", "block_sparse_attention_ref",
    "decode_attention_ref", "dense_attention_ref",
]
