"""Pallas TPU kernels for the paper's compute hot-spot (block-sparse prefill
attention) plus pure-jnp oracles.

  block_sparse_attn.py  pl.pallas_call + BlockSpec splash-style kernel
  ops.py                jit'd wrappers (index staging, Ã scatter)
  ref.py                pure-jnp oracles the kernels are validated against
"""
from repro.kernels.ops import (
    block_sparse_attention,
    build_block_tables,
    make_attention_fn,
    scatter_block_stats,
)
from repro.kernels.ref import (
    block_sparse_attention_ref,
    decode_attention_ref,
    dense_attention_ref,
)

__all__ = [
    "block_sparse_attention", "build_block_tables", "make_attention_fn",
    "scatter_block_stats", "block_sparse_attention_ref",
    "decode_attention_ref", "dense_attention_ref",
]
