"""Parameter PartitionSpec assignment (FSDP over ``data`` + TP over ``model``).

Leaves are matched by their pytree path suffix; sizes not divisible by the
target mesh axes fall back to replication for that dim.  Stacked layer params
(any path containing a ``stack`` key) get a leading replicated dim.

The default policy is 2-D sharding: the TP dim (heads / ffn hidden / experts /
vocab) over ``model`` and the other large dim over ``data`` (ZeRO-3-style
FSDP) — this is what lets a 123B-dense or 244B-MoE model fit a 256-chip v5e
pod at bf16 (see EXPERIMENTS.md §Dry-run).  Inference can switch FSDP off
(``fsdp=False``) to avoid per-layer weight all-gathers — one of the §Perf
hillclimb levers.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import ShardingRules

# (last-key match, per-dim logical axes) — dims counted from the END so the
# same rule covers stacked ((L,) + shape) and unstacked leaves.
_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    ("embed", ("vocab", "fsdp")),
    ("lm_head", ("fsdp", "vocab")),
    ("wq", ("fsdp", "tp", None)),
    ("w_q", ("fsdp", "tp", None)),
    ("wk", ("fsdp", "tp", None)),
    ("wv", ("fsdp", "tp", None)),
    ("wo", ("tp", None, "fsdp")),
    ("w_gate", ("fsdp", "tp")),         # dense mlp (2D)
    ("w_up", ("fsdp", "tp")),
    ("w_down", ("tp", "fsdp")),
    ("router", ("fsdp", None)),
    ("w_kv_down", ("fsdp", None)),
    ("w_q_down", ("fsdp", None)),
    ("w_q_up", (None, "tp", None)),
    ("w_uk", (None, "tp", None)),
    ("w_uv", (None, "tp", None)),
    ("w_in", ("fsdp", "tp")),
    ("w_x", ("fsdp", "tp")),
    ("w_a", ("tp", None)),
    ("w_i", ("tp", None)),
    ("w_out", ("tp", "fsdp")),
    ("conv_w", (None, "tp")),
)

# MoE expert stacks are 3-D with a leading expert dim.  When the expert
# count does not divide the model axis (Mixtral: 8 experts on 16 chips) the
# fallback shards the FFN hidden dim instead — otherwise the expert weights
# replicate at 270 GB/device (§Perf iteration 3).
_MOE_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    ("w_gate", ("experts", "fsdp", None)),
    ("w_up", ("experts", "fsdp", None)),
    ("w_down", ("experts", None, "fsdp")),
)
_MOE_FALLBACK: dict = {
    "w_gate": (None, "fsdp", "tp"),
    "w_up": (None, "fsdp", "tp"),
    "w_down": (None, "tp", "fsdp"),
}


def _axes_for(logical: Optional[str], *, fsdp: bool
              ) -> Optional[Tuple[str, ...]]:
    if logical is None:
        return None
    if logical in ("tp", "vocab", "experts"):
        return ("model",)
    if logical == "fsdp":
        return ("data",) if fsdp else None
    return None


def _path_keys(path) -> Tuple[str, ...]:
    return tuple(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def leaf_pspec(path_keys: Tuple[str, ...], shape: Tuple[int, ...],
               mesh: Mesh, *, fsdp: bool = True) -> P:
    last = path_keys[-1]
    ndim = len(shape)
    is_moe_expert = (last in ("w_gate", "w_up", "w_down")
                     and "ffn" in path_keys and ndim >= 3
                     and "shared" not in path_keys)
    rules = _MOE_RULES if is_moe_expert else _RULES
    if is_moe_expert:
        expert_dim = shape[ndim - 3]
        if expert_dim % mesh.shape.get("model", 1):
            rules = ((last, _MOE_FALLBACK[last]),)
    for name, dims in rules:
        if last == name and ndim >= len(dims):
            parts: list = [None] * ndim
            for i, logical in enumerate(dims):
                dim_idx = ndim - len(dims) + i
                axes = _axes_for(logical, fsdp=fsdp)
                if axes is None:
                    continue
                size = int(np.prod([mesh.shape[a] for a in axes]))
                if shape[dim_idx] % size == 0:
                    parts[dim_idx] = axes[0] if len(axes) == 1 else axes
            return P(*parts)
    return P()          # replicate (norms, biases, small vectors)


def param_pspecs(params_shape: Any, mesh: Mesh, *, fsdp: bool = True) -> Any:
    """Map a params pytree (of arrays or ShapeDtypeStructs) to PartitionSpecs."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = [leaf_pspec(_path_keys(p), tuple(x.shape), mesh, fsdp=fsdp)
             for p, x in flat]
    return jax.tree.unflatten(treedef, specs)


def param_shardings(params_shape: Any, mesh: Mesh, *, fsdp: bool = True
                    ) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_pspecs(params_shape, mesh, fsdp=fsdp))


# --------------------------------------------------------------------------
# Cache / batch specs
# --------------------------------------------------------------------------

def batch_pspec(mesh: Mesh, batch: int) -> P:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    if axes and batch % size == 0:
        return P(axes if len(axes) > 1 else axes[0])
    return P()


def cache_pspec(shape: Tuple[int, ...], mesh: Mesh, *, batch: int,
                stacked: bool) -> P:
    """KV-cache leaf spec: batch over (pod,data); ONE of {kv_heads, head_dim,
    seq} over model (priority order, divisibility-gated); long-context
    batch=1 caches shard seq over (pod,data) instead."""
    dims = list(shape)
    parts: list = [None] * len(dims)
    i0 = 1 if stacked else 0
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dsize = int(np.prod([mesh.shape[a] for a in data_axes])) if data_axes \
        else 1
    msize = mesh.shape.get("model", 1)

    batch_idx = i0
    used_data = False
    if data_axes and dims[batch_idx] % dsize == 0 and dims[batch_idx] > 1:
        parts[batch_idx] = (data_axes if len(data_axes) > 1
                            else data_axes[0])
        used_data = True

    # choose one dim for the model axis: kv_heads > head_dim > seq
    rest = list(range(i0 + 1, len(dims)))
    model_dim = None
    if len(dims) - i0 == 4:              # (B, Hkv, S, hd)
        for cand in (i0 + 1, i0 + 3, i0 + 2):
            if dims[cand] % msize == 0 and dims[cand] >= msize:
                model_dim = cand
                break
    elif len(dims) - i0 == 3:            # (B, S, R) MLA latent
        for cand in (i0 + 1, i0 + 2):
            if dims[cand] % msize == 0 and dims[cand] >= msize:
                model_dim = cand
                break
    if model_dim is not None and "model" in mesh.axis_names:
        parts[model_dim] = "model"

    # batch=1 long decode: context-parallel the seq dim over (pod, data)
    if not used_data and data_axes and len(dims) - i0 >= 3:
        seq_idx = i0 + 2 if len(dims) - i0 == 4 else i0 + 1
        if parts[seq_idx] is None and dims[seq_idx] % dsize == 0 \
                and dims[seq_idx] >= dsize:
            parts[seq_idx] = (data_axes if len(data_axes) > 1
                              else data_axes[0])
    return P(*parts)


def cache_shardings(cache_shape: Any, mesh: Mesh, *, batch: int) -> Any:
    def one(x):
        stacked = len(x.shape) >= 1 and x.shape[0] != batch and \
            (len(x.shape) >= 4 or (len(x.shape) == 3 and x.shape[1] == batch))
        # stacked iff dim0 is the layer-stack dim (batch appears at dim1)
        st = (len(x.shape) >= 2 and x.shape[0] != batch
              and x.shape[1] == batch)
        return NamedSharding(mesh, cache_pspec(tuple(x.shape), mesh,
                                               batch=batch, stacked=st))
    return jax.tree.map(one, cache_shape)
