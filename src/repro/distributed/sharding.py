"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Models annotate activations/params with *logical* axis names via
:func:`shard`; a :class:`ShardingRules` context maps logical names to mesh
axes.  Outside a rules context the annotations are no-ops, so the same model
code runs unsharded on one CPU device (smoke tests) and fully sharded on the
(pod, data, model) production mesh (dry-run / launch).

The rules context also drives the **mesh-active routing rule**
(:func:`active_model_mesh`): when the context's "model" axis is non-trivial,
the serving hot paths resolve their ``shard_map`` twins automatically —
sparse prefill through :func:`sharded_batched_block_sparse_attention`,
sparse decode through :func:`sharded_flash_decode` — each building/consuming
its splash index tables per head shard, so SMEM stays O(local heads) and
outputs stay bitwise-equal to the single-device paths.

Logical axes:
  batch        DP over ("pod", "data") — training/prefill/decode batch
  seq          context parallelism — long-decode KV-cache sequence
  heads        TP over "model" — attention heads
  kv_heads     TP over "model" (GQA: may be smaller than the axis → replicate)
  embed        replicated activation feature dim
  mlp          TP over "model" — FFN hidden
  experts      expert parallelism over "model"
  vocab        TP over "model" — embedding/logits
  ssm_inner    TP over "model" — SSM/RG-LRU channel dim
  stack        layer-stack dim of scanned params (never sharded)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

DEFAULT_RULES: Dict[str, Optional[Tuple[str, ...]]] = {
    "batch": ("pod", "data"),
    "seq": ("data",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "embed": None,
    "mlp": ("model",),
    "experts": ("model",),
    "expert_cap": None,
    "vocab": ("model",),
    "ssm_inner": ("model",),
    "ssm_state": None,
    "stack": None,
    "blocks_q": None,
    "blocks_kv": None,
    "clusters": None,
}


class ShardingRules:
    def __init__(self, mesh: Mesh,
                 overrides: Optional[Dict[str, Optional[Tuple[str, ...]]]]
                 = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if overrides:
            self.rules.update(overrides)
        axes = set(mesh.axis_names)
        # drop mesh axes the current mesh does not have (e.g. "pod" single-pod)
        for k, v in list(self.rules.items()):
            if v is None:
                continue
            kept = tuple(a for a in v if a in axes)
            self.rules[k] = kept if kept else None

    def spec(self, *logical: Optional[str]) -> P:
        parts = []
        for name in logical:
            if name is None:
                parts.append(None)
                continue
            axes = self.rules.get(name)
            if axes is None:
                parts.append(None)
            elif len(axes) == 1:
                parts.append(axes[0])
            else:
                parts.append(axes)
        return P(*parts)

    def sharding(self, *logical: Optional[str]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*logical))


def current_rules() -> Optional[ShardingRules]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield rules
    finally:
        _state.rules = prev


def head_shard_count(mesh: Mesh, axis: str, num_heads: int,
                     num_kv_heads: int) -> int:
    """Usable shard count of ``axis`` for head-parallel attention: the mesh
    axis size when both head counts divide it (each shard gets whole GQA
    groups), else 1 (replicate — same fallback rule as :func:`shard`)."""
    if axis not in mesh.axis_names:
        return 1
    n = mesh.shape[axis]
    if n <= 1 or num_heads % n or num_kv_heads % n:
        return 1
    return n


def active_model_mesh(axis: str = "model") -> Optional[Mesh]:
    """The **mesh-active routing rule**, shared by sparse prefill and sparse
    decode: return the active rules context's mesh when its ``axis`` is
    non-trivial (size > 1), else None.

    Both hot paths resolve their sharded twin from this single predicate —
    :func:`repro.models.attention.resolve_attention_fn` routes the prefill
    kernel through :func:`sharded_batched_block_sparse_attention`, and
    :func:`repro.models.attention.attention_decode` routes a DecodePlan step
    through :func:`sharded_flash_decode` — so a served model runs prefill
    *and* decode under the same mesh with no per-call configuration.  Head
    counts that do not divide the axis still fall back to the single-device
    path (see :func:`head_shard_count`).
    """
    rules = current_rules()
    if rules is None or axis not in rules.mesh.axis_names:
        return None
    return rules.mesh if rules.mesh.shape[axis] > 1 else None


def shardable_model_mesh(num_heads: int, num_kv_heads: int,
                         axis: str = "model") -> Optional[Mesh]:
    """The mesh-active routing predicate with head divisibility folded in:
    the active rules context's mesh when its ``axis`` is non-trivial AND
    both head counts shard over it (whole GQA groups per shard —
    :func:`head_shard_count`), else None.

    Sparse-decode plan *construction* (``build_decode_plan_auto``) and plan
    *execution* (``attention_decode``) both resolve through this single
    helper, so a sharded-laid-out plan is always consumed by the sharded
    path and vice versa — the lockstep is structural, not copy-paste.
    """
    mesh = active_model_mesh(axis)
    if mesh is None or head_shard_count(mesh, axis, num_heads,
                                        num_kv_heads) <= 1:
        return None
    return mesh


def sharded_batched_block_sparse_attention(
    q: jax.Array,               # (B, H, N, Dqk)
    k: jax.Array,               # (B, Hkv, N, Dqk)
    v: jax.Array,               # (B, Hkv, N, Dv)
    block_mask: jax.Array,      # (B, H, NBq, NBkv) bool
    *,
    mesh: Mesh,
    axis: str = "model",
    block_size: int,
    causal: bool = True,
    width: Optional[int] = None,
    interpret: bool = True,
    stats_gate: Optional[jax.Array] = None,     # (B, H)
):
    """Heads-sharded batch-native block-sparse prefill attention.

    Runs :func:`repro.kernels.ops.batched_block_sparse_attention` under
    ``shard_map`` with every head-indexed operand partitioned over ``axis``.
    The splash ``(indices, counts)`` tables are built *inside* the shard
    body from the local mask slice, so the kernel's scalar-prefetch SMEM
    footprint is O(local heads) — a device never materializes another
    shard's tables (the multi-host table-size concern deferred since PR 1).
    Head-parallel attention has no cross-shard reductions, so outputs match
    the single-device path exactly.

    Requires ``head_shard_count(mesh, axis, H, Hkv) > 1``; callers (e.g.
    :func:`repro.kernels.batched_sparse_attention_fn`) are expected to fall
    back to the single-device path otherwise.
    """
    from jax.experimental.shard_map import shard_map

    from repro.kernels.ops import batched_block_sparse_attention

    if head_shard_count(mesh, axis, q.shape[1], k.shape[1]) <= 1:
        raise ValueError(
            f"head counts {q.shape[1]}/{k.shape[1]} do not shard over mesh "
            f"axis {axis!r} of {mesh.shape}")
    if stats_gate is None:
        stats_gate = jnp.ones(q.shape[:2], jnp.int32)

    def body(q_l, k_l, v_l, m_l, g_l):
        return batched_block_sparse_attention(
            q_l, k_l, v_l, m_l, block_size=block_size, causal=causal,
            interpret=interpret, width=width, stats_gate=g_l)

    hs = P(None, axis)
    return shard_map(
        body, mesh=mesh,
        in_specs=(hs, hs, hs, hs, hs),
        out_specs=(hs, hs),
        check_rep=False,
    )(q, k, v, block_mask, stats_gate)


def sharded_flash_decode(
    q: jax.Array,               # (B, H, D) one token per sequence
    cache_k: jax.Array,         # (B, Hkv, S, D)
    cache_v: jax.Array,         # (B, Hkv, S, Dv)
    plan,                       # DecodePlan, one layer's (B, Hkv, …) slice
    valid: jax.Array,           # (B, S) bool slot validity
    *,
    mesh: Mesh,
    axis: str = "model",
    impl: str = "auto",
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Heads-sharded sparse decode over prebuilt DecodePlan tables.

    Runs :func:`repro.kernels.decode_attn.flash_decode_plan` under
    ``shard_map`` with every head-indexed operand — queries, the grouped KV
    cache, and the scalar-prefetched ``(indices, counts, keep_heads)``
    tables — partitioned over ``axis``; the slot-validity vector is
    replicated.  Each device's kernel invocation sees only its local
    kv-heads' tables, so the scalar-prefetch SMEM footprint stays O(local
    heads) — the decode analogue of
    :func:`sharded_batched_block_sparse_attention`, and the execution half
    of the per-shard tables that ``build_decode_plan(kv_head_range=...)``
    produces.  Head-parallel decode has no cross-shard reductions, so the
    output equals the single-device plan path bitwise.

    Requires ``head_shard_count(mesh, axis, H, Hkv) > 1``; callers (e.g.
    :func:`repro.models.attention.attention_decode`) fall back to the
    single-device :func:`flash_decode_plan` otherwise.  MLA latent caches
    and the hybrid ring-buffer layouts never reach this function — they
    decode densely (no DecodePlan is built for them), so the carve-out
    lives at the dispatch site, not here.

    Returns (B, H, Dv).
    """
    from jax.experimental.shard_map import shard_map

    from repro.kernels.decode_attn import DecodePlan, flash_decode_plan

    if head_shard_count(mesh, axis, q.shape[1], cache_k.shape[1]) <= 1:
        raise ValueError(
            f"head counts {q.shape[1]}/{cache_k.shape[1]} do not shard over "
            f"mesh axis {axis!r} of {mesh.shape}")

    def body(q_l, k_l, v_l, idx_l, cnt_l, keep_l, valid_l):
        return flash_decode_plan(q_l, k_l, v_l,
                                 DecodePlan(idx_l, cnt_l, keep_l),
                                 valid_l, impl=impl, interpret=interpret)

    hs = P(None, axis)
    return shard_map(
        body, mesh=mesh,
        in_specs=(hs, hs, hs, hs, hs, hs, P(None, None)),
        out_specs=hs,
        check_rep=False,
    )(q, cache_k, cache_v, plan.indices, plan.counts, plan.keep_heads, valid)


def sharded_flash_decode_paged(
    q: jax.Array,               # (B, H, D) one token per slot
    pool_k: jax.Array,          # (P, Hkv, ps, D) shared page pool
    pool_v: jax.Array,          # (P, Hkv, ps, Dv)
    page_table: jax.Array,      # (B, NB) int32
    plan,                       # DecodePlan, one layer's (B, Hkv, …) slice
    valid: jax.Array,           # (B, NB·ps) bool
    *,
    mesh: Mesh,
    axis: str = "model",
    impl: str = "auto",
    interpret: Optional[bool] = None,
) -> jax.Array:
    """:func:`sharded_flash_decode` over a block-paged KV cache.

    The page pool's heads axis (axis 1 of ``(P, Hkv, ps, D)``) shards over
    ``axis`` exactly like the contiguous cache's — the same ``P(None,
    axis)`` spec — while the page table and slot validity replicate: page
    residency is a per-slot property, not a per-head one.  Each device
    walks its local kv-heads' logical block tables through the (replicated)
    page table into its local pool shard; head-parallel decode has no
    cross-shard reductions, so the output equals the single-device
    :func:`repro.kernels.decode_attn.flash_decode_plan_paged` bitwise.
    """
    from jax.experimental.shard_map import shard_map

    from repro.kernels.decode_attn import DecodePlan, flash_decode_plan_paged

    if head_shard_count(mesh, axis, q.shape[1], pool_k.shape[1]) <= 1:
        raise ValueError(
            f"head counts {q.shape[1]}/{pool_k.shape[1]} do not shard over "
            f"mesh axis {axis!r} of {mesh.shape}")

    def body(q_l, k_l, v_l, pt_l, idx_l, cnt_l, keep_l, valid_l):
        return flash_decode_plan_paged(q_l, k_l, v_l, pt_l,
                                       DecodePlan(idx_l, cnt_l, keep_l),
                                       valid_l, impl=impl,
                                       interpret=interpret)

    hs = P(None, axis)
    rep = P(None, None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(hs, hs, hs, rep, hs, hs, hs, rep),
        out_specs=hs,
        check_rep=False,
    )(q, pool_k, pool_v, page_table, plan.indices, plan.counts,
      plan.keep_heads, valid)


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Annotate with a sharding constraint if a rules context is active.

    ``len(logical)`` may be shorter than ``x.ndim``; missing trailing axes are
    treated as replicated.  Sizes not divisible by the mapped mesh axes fall
    back to replication for that dim (e.g. 8 kv heads on a 16-way model axis).
    """
    rules = current_rules()
    if rules is None:
        return x
    logical = tuple(logical) + (None,) * (x.ndim - len(logical))
    parts = []
    used: set = set()
    for dim, name in zip(x.shape, logical):
        if name is None:
            parts.append(None)
            continue
        axes = rules.rules.get(name)
        if axes:
            # a mesh axis may appear at most once per spec: first dim wins
            axes = tuple(a for a in axes if a not in used)
        if not axes:
            parts.append(None)
            continue
        size = 1
        for a in axes:
            size *= rules.mesh.shape[a]
        if dim % size != 0:
            parts.append(None)
        else:
            used.update(axes)
            parts.append(axes[0] if len(axes) == 1 else axes)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, P(*parts)))
