"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Models annotate activations/params with *logical* axis names via
:func:`shard`; a :class:`ShardingRules` context maps logical names to mesh
axes.  Outside a rules context the annotations are no-ops, so the same model
code runs unsharded on one CPU device (smoke tests) and fully sharded on the
(pod, data, model) production mesh (dry-run / launch).

Logical axes:
  batch        DP over ("pod", "data") — training/prefill/decode batch
  seq          context parallelism — long-decode KV-cache sequence
  heads        TP over "model" — attention heads
  kv_heads     TP over "model" (GQA: may be smaller than the axis → replicate)
  embed        replicated activation feature dim
  mlp          TP over "model" — FFN hidden
  experts      expert parallelism over "model"
  vocab        TP over "model" — embedding/logits
  ssm_inner    TP over "model" — SSM/RG-LRU channel dim
  stack        layer-stack dim of scanned params (never sharded)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

DEFAULT_RULES: Dict[str, Optional[Tuple[str, ...]]] = {
    "batch": ("pod", "data"),
    "seq": ("data",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "embed": None,
    "mlp": ("model",),
    "experts": ("model",),
    "expert_cap": None,
    "vocab": ("model",),
    "ssm_inner": ("model",),
    "ssm_state": None,
    "stack": None,
    "blocks_q": None,
    "blocks_kv": None,
    "clusters": None,
}


class ShardingRules:
    def __init__(self, mesh: Mesh,
                 overrides: Optional[Dict[str, Optional[Tuple[str, ...]]]]
                 = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if overrides:
            self.rules.update(overrides)
        axes = set(mesh.axis_names)
        # drop mesh axes the current mesh does not have (e.g. "pod" single-pod)
        for k, v in list(self.rules.items()):
            if v is None:
                continue
            kept = tuple(a for a in v if a in axes)
            self.rules[k] = kept if kept else None

    def spec(self, *logical: Optional[str]) -> P:
        parts = []
        for name in logical:
            if name is None:
                parts.append(None)
                continue
            axes = self.rules.get(name)
            if axes is None:
                parts.append(None)
            elif len(axes) == 1:
                parts.append(axes[0])
            else:
                parts.append(axes)
        return P(*parts)

    def sharding(self, *logical: Optional[str]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*logical))


def current_rules() -> Optional[ShardingRules]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield rules
    finally:
        _state.rules = prev


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Annotate with a sharding constraint if a rules context is active.

    ``len(logical)`` may be shorter than ``x.ndim``; missing trailing axes are
    treated as replicated.  Sizes not divisible by the mapped mesh axes fall
    back to replication for that dim (e.g. 8 kv heads on a 16-way model axis).
    """
    rules = current_rules()
    if rules is None:
        return x
    logical = tuple(logical) + (None,) * (x.ndim - len(logical))
    parts = []
    used: set = set()
    for dim, name in zip(x.shape, logical):
        if name is None:
            parts.append(None)
            continue
        axes = rules.rules.get(name)
        if axes:
            # a mesh axis may appear at most once per spec: first dim wins
            axes = tuple(a for a in axes if a not in used)
        if not axes:
            parts.append(None)
            continue
        size = 1
        for a in axes:
            size *= rules.mesh.shape[a]
        if dim % size != 0:
            parts.append(None)
        else:
            used.update(axes)
            parts.append(axes[0] if len(axes) == 1 else axes)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, P(*parts)))
