"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Models annotate activations/params with *logical* axis names via
:func:`shard`; a :class:`ShardingRules` context maps logical names to mesh
axes.  Outside a rules context the annotations are no-ops, so the same model
code runs unsharded on one CPU device (smoke tests) and fully sharded on the
(pod, data, model) production mesh (dry-run / launch).

Logical axes:
  batch        DP over ("pod", "data") — training/prefill/decode batch
  seq          context parallelism — long-decode KV-cache sequence
  heads        TP over "model" — attention heads
  kv_heads     TP over "model" (GQA: may be smaller than the axis → replicate)
  embed        replicated activation feature dim
  mlp          TP over "model" — FFN hidden
  experts      expert parallelism over "model"
  vocab        TP over "model" — embedding/logits
  ssm_inner    TP over "model" — SSM/RG-LRU channel dim
  stack        layer-stack dim of scanned params (never sharded)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

DEFAULT_RULES: Dict[str, Optional[Tuple[str, ...]]] = {
    "batch": ("pod", "data"),
    "seq": ("data",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "embed": None,
    "mlp": ("model",),
    "experts": ("model",),
    "expert_cap": None,
    "vocab": ("model",),
    "ssm_inner": ("model",),
    "ssm_state": None,
    "stack": None,
    "blocks_q": None,
    "blocks_kv": None,
    "clusters": None,
}


class ShardingRules:
    def __init__(self, mesh: Mesh,
                 overrides: Optional[Dict[str, Optional[Tuple[str, ...]]]]
                 = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if overrides:
            self.rules.update(overrides)
        axes = set(mesh.axis_names)
        # drop mesh axes the current mesh does not have (e.g. "pod" single-pod)
        for k, v in list(self.rules.items()):
            if v is None:
                continue
            kept = tuple(a for a in v if a in axes)
            self.rules[k] = kept if kept else None

    def spec(self, *logical: Optional[str]) -> P:
        parts = []
        for name in logical:
            if name is None:
                parts.append(None)
                continue
            axes = self.rules.get(name)
            if axes is None:
                parts.append(None)
            elif len(axes) == 1:
                parts.append(axes[0])
            else:
                parts.append(axes)
        return P(*parts)

    def sharding(self, *logical: Optional[str]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*logical))


def current_rules() -> Optional[ShardingRules]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield rules
    finally:
        _state.rules = prev


def head_shard_count(mesh: Mesh, axis: str, num_heads: int,
                     num_kv_heads: int) -> int:
    """Usable shard count of ``axis`` for head-parallel attention: the mesh
    axis size when both head counts divide it (each shard gets whole GQA
    groups), else 1 (replicate — same fallback rule as :func:`shard`)."""
    if axis not in mesh.axis_names:
        return 1
    n = mesh.shape[axis]
    if n <= 1 or num_heads % n or num_kv_heads % n:
        return 1
    return n


def sharded_batched_block_sparse_attention(
    q: jax.Array,               # (B, H, N, Dqk)
    k: jax.Array,               # (B, Hkv, N, Dqk)
    v: jax.Array,               # (B, Hkv, N, Dv)
    block_mask: jax.Array,      # (B, H, NBq, NBkv) bool
    *,
    mesh: Mesh,
    axis: str = "model",
    block_size: int,
    causal: bool = True,
    width: Optional[int] = None,
    interpret: bool = True,
    stats_gate: Optional[jax.Array] = None,     # (B, H)
):
    """Heads-sharded batch-native block-sparse prefill attention.

    Runs :func:`repro.kernels.ops.batched_block_sparse_attention` under
    ``shard_map`` with every head-indexed operand partitioned over ``axis``.
    The splash ``(indices, counts)`` tables are built *inside* the shard
    body from the local mask slice, so the kernel's scalar-prefetch SMEM
    footprint is O(local heads) — a device never materializes another
    shard's tables (the multi-host table-size concern deferred since PR 1).
    Head-parallel attention has no cross-shard reductions, so outputs match
    the single-device path exactly.

    Requires ``head_shard_count(mesh, axis, H, Hkv) > 1``; callers (e.g.
    :func:`repro.kernels.batched_sparse_attention_fn`) are expected to fall
    back to the single-device path otherwise.
    """
    from jax.experimental.shard_map import shard_map

    from repro.kernels.ops import batched_block_sparse_attention

    if head_shard_count(mesh, axis, q.shape[1], k.shape[1]) <= 1:
        raise ValueError(
            f"head counts {q.shape[1]}/{k.shape[1]} do not shard over mesh "
            f"axis {axis!r} of {mesh.shape}")
    if stats_gate is None:
        stats_gate = jnp.ones(q.shape[:2], jnp.int32)

    def body(q_l, k_l, v_l, m_l, g_l):
        return batched_block_sparse_attention(
            q_l, k_l, v_l, m_l, block_size=block_size, causal=causal,
            interpret=interpret, width=width, stats_gate=g_l)

    hs = P(None, axis)
    return shard_map(
        body, mesh=mesh,
        in_specs=(hs, hs, hs, hs, hs),
        out_specs=(hs, hs),
        check_rep=False,
    )(q, k, v, block_mask, stats_gate)


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Annotate with a sharding constraint if a rules context is active.

    ``len(logical)`` may be shorter than ``x.ndim``; missing trailing axes are
    treated as replicated.  Sizes not divisible by the mapped mesh axes fall
    back to replication for that dim (e.g. 8 kv heads on a 16-way model axis).
    """
    rules = current_rules()
    if rules is None:
        return x
    logical = tuple(logical) + (None,) * (x.ndim - len(logical))
    parts = []
    used: set = set()
    for dim, name in zip(x.shape, logical):
        if name is None:
            parts.append(None)
            continue
        axes = rules.rules.get(name)
        if axes:
            # a mesh axis may appear at most once per spec: first dim wins
            axes = tuple(a for a in axes if a not in used)
        if not axes:
            parts.append(None)
            continue
        size = 1
        for a in axes:
            size *= rules.mesh.shape[a]
        if dim % size != 0:
            parts.append(None)
        else:
            used.update(axes)
            parts.append(axes[0] if len(axes) == 1 else axes)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, P(*parts)))
