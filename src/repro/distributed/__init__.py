from repro.distributed.sharding import (
    DEFAULT_RULES,
    ShardingRules,
    current_rules,
    shard,
    use_rules,
)

__all__ = ["DEFAULT_RULES", "ShardingRules", "current_rules", "shard",
           "use_rules"]
