"""Pytest plugin: pin ``PYTHONHASHSEED`` by re-exec'ing the interpreter.

Loaded via ``addopts = "-p repro.hashseed_pin"`` (pyproject.toml), so the
import-time side effect below runs during pytest's *preparse* — before the
capture plugin swaps the process's stdout/stderr fds (re-exec'ing any later,
e.g. from ``conftest.py``, would strand all test output in the dead
process's capture tempfile).

Why pin at all: the tiny smoke models the suite serves sit on argmax knife
edges — several vocabulary entries land within float ulps of each other —
and jax/XLA trace construction is sensitive to Python's randomized string
hashing (set/dict ordering inside the tracer perturbs HLO instruction
order, which perturbs CPU reduction order by last-ulp amounts).  Under a
random hash seed the greedy token streams, and with them every
cross-engine bitwise-equivalence test, differ from one ``pytest``
invocation to the next: a handful of tests become coin flips.  Pinning the
seed makes the tier-1 suite a pure function of the tree.

An externally-set ``PYTHONHASHSEED`` is respected (no re-exec), so a
deliberate seed sweep is still one env var away.
"""
import os
import sys

if os.environ.get("PYTHONHASHSEED") is None:
    os.environ["PYTHONHASHSEED"] = "1"
    if os.path.basename(sys.argv[0]) == "__main__.py":
        # ``python -m pytest``: relaunch through -m so sys.path keeps cwd
        argv = [sys.executable, "-m", "pytest"] + sys.argv[1:]
    else:
        # console-script entry point (argv[0] is the pytest shim script)
        argv = [sys.executable] + sys.argv
    os.execv(sys.executable, argv)
