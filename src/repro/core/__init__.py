"""SharePrefill core: the paper's primary contribution in JAX.

Modules:
  patterns         block-sparse pattern algebra (masks, cumulative-γ top-k)
  jsd              Jensen-Shannon distance (d_sparse / d_sim)
  vertical_slash   Algorithm 5 — cumulative-threshold vertical-slash search
  determine        Algorithm 3 — per-head pattern decision
  construct        Algorithm 2 — pivotal pattern construction
  pattern_dict     the dynamic pivotal-pattern dictionary as a pytree
  share_attention  Algorithm 1 — per-layer orchestration
  clustering       offline head clustering (autoencoder + agglomerative)
  api              SharePrefill — the packaged module models consume
"""
from repro.core.api import SharePrefill
from repro.core.pattern_dict import PivotalState, init_pivotal_state
from repro.core.share_attention import (
    LayerStats,
    batched_share_prefill_attention_layer,
    gqa_head_vmap,
    share_prefill_attention_layer,
)

__all__ = [
    "SharePrefill", "PivotalState", "init_pivotal_state", "LayerStats",
    "share_prefill_attention_layer", "batched_share_prefill_attention_layer",
    "gqa_head_vmap",
]
