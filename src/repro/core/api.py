"""Public API of the SharePrefill core.

Models consume the technique through :class:`SharePrefill`: built once from a
config + offline clustering artifact, it provides (a) an initial pattern-dict
state and (b) a per-layer attention callable suitable for use as the body of
a ``lax.scan`` over layers.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import SharePrefillConfig
from repro.core import share_attention as sa
from repro.core.pattern_dict import PivotalState
from repro.core.patterns import num_blocks


@dataclasses.dataclass(frozen=True)
class SharePrefill:
    """The paper's technique, packaged as a composable module.

    Attributes:
      cfg: thresholds (γ, τ, δ) and block size.
      cluster_ids: (L, H) int32 head_dict from offline clustering (-1 noise).
      num_clusters: number of non-noise clusters.
    """

    cfg: SharePrefillConfig
    cluster_ids: np.ndarray
    num_clusters: int

    @staticmethod
    def disabled() -> "SharePrefill":
        return SharePrefill(SharePrefillConfig(enabled=False),
                            np.zeros((0, 0), np.int32), 1)

    @staticmethod
    def from_clustering(cfg: SharePrefillConfig, cluster_ids: np.ndarray,
                        num_clusters: int) -> "SharePrefill":
        return SharePrefill(cfg, np.asarray(cluster_ids, np.int32),
                            max(int(num_clusters), 1))

    @staticmethod
    def trivial(cfg: SharePrefillConfig, num_layers: int,
                num_heads: int) -> "SharePrefill":
        """Head-index-tied default clusters (head h of every layer shares a
        cluster) — used before an offline clustering artifact exists.

        C = num_heads keeps the pattern-dict state O(H·NB²) instead of
        O(L·H·NB²): with one-cluster-per-(layer, head) the dictionary grew
        to 2.7 GB/layer of all-reduced state for qwen2-vl-72b at 32k
        (§Perf iteration 4).  The τ-similarity check still gates every
        share, so a wrong prior degrades to vertical-slash, not to errors."""
        ids = np.tile(np.arange(num_heads, dtype=np.int32),
                      (num_layers, 1))
        return SharePrefill(cfg, ids, num_heads)

    # ------------------------------------------------------------------
    def applicable(self, seq_len: int) -> bool:
        if not self.cfg.enabled:
            return False
        nb = seq_len // self.cfg.block_size
        return (seq_len % self.cfg.block_size == 0
                and nb >= self.cfg.min_seq_blocks)

    def init_state(self, batch: int, seq_len: int) -> PivotalState:
        nb = num_blocks(seq_len, self.cfg.block_size)
        return sa.init_batched_state(batch, self.num_clusters, nb)

    def layer_attention(
        self,
        layer_idx_or_ids,
        q: jnp.ndarray,                 # (B, H, N, D)
        k: jnp.ndarray,                 # (B, Hkv, N, D) — un-expanded heads
        v: jnp.ndarray,
        state: PivotalState,
        attention_fn: Optional[sa.AttentionFn] = None,
        extra_mask: Optional[jnp.ndarray] = None,
    ) -> Tuple[jnp.ndarray, PivotalState, sa.LayerStats]:
        """Run one layer of SharePrefill attention.

        ``layer_idx_or_ids`` is either a static int (cluster ids are looked up
        host-side) or a traced (H,) int32 array (the scan-xs path).
        ``attention_fn=None`` selects the batch-native sparse execution
        backend (:func:`repro.kernels.batched_sparse_attention_fn` at
        ``cfg.block_size`` — one fused kernel call for the whole batch).
        """
        if isinstance(layer_idx_or_ids, int):
            ids = jnp.asarray(self.cluster_ids[layer_idx_or_ids])
        else:
            ids = layer_idx_or_ids
        return sa.batched_share_prefill_attention_layer(
            q, k, v, state, ids, self.cfg, attention_fn, extra_mask)

    def layer_cluster_ids(self) -> jnp.ndarray:
        """(L, H) scan-xs array of cluster ids."""
        return jnp.asarray(self.cluster_ids)
