"""The dynamic pivotal-pattern dictionary as a fixed-shape pytree.

The paper maintains a Python dict ``cluster → (ã, M)`` mutated layer-by-layer
during prefill.  The JAX version is a :class:`PivotalState` carried through a
``lax.scan`` over layers; lookups are gathers by cluster id and updates are
one-hot scatters, which GSPMD turns into the all-reduce merge that realizes
the paper's "global dictionary shared across devices" future-work proposal
(DESIGN.md §4).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax.numpy as jnp


class PivotalState(NamedTuple):
    """pivotal_pattern_dict: cluster → (M, ã) plus validity flags."""

    masks: jnp.ndarray   # (C, NB, NB) bool — pivotal patterns M
    reps: jnp.ndarray    # (C, NB) f32 — pivotal representatives ã
    valid: jnp.ndarray   # (C,) bool — pivot exists for this cluster

    @property
    def num_clusters(self) -> int:
        return self.masks.shape[0]


def init_pivotal_state(num_clusters: int, nb: int,
                       dtype=jnp.float32) -> PivotalState:
    return PivotalState(
        masks=jnp.zeros((num_clusters, nb, nb), dtype=bool),
        reps=jnp.full((num_clusters, nb), 1.0 / nb, dtype=dtype),
        valid=jnp.zeros((num_clusters,), dtype=bool),
    )


def lookup(state: PivotalState, cluster_ids: jnp.ndarray
           ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Gather (M, ã, valid) for each head; noise ids (-1) read slot 0 but are
    masked invalid."""
    safe = jnp.clip(cluster_ids, 0, state.num_clusters - 1)
    masks = jnp.take(state.masks, safe, axis=0)
    reps = jnp.take(state.reps, safe, axis=0)
    valid = jnp.take(state.valid, safe, axis=0) & (cluster_ids >= 0)
    return masks, reps, valid


def update(state: PivotalState,
           cluster_ids: jnp.ndarray,      # (H,)
           new_masks: jnp.ndarray,        # (H, NB, NB) bool
           new_reps: jnp.ndarray,         # (H, NB)
           should_update: jnp.ndarray,    # (H,) bool — heads that ran dense
           ) -> PivotalState:
    """One-hot scatter update; at most one head per cluster updates per layer
    (the first head), so the weighted sums are exact."""
    c = state.num_clusters
    onehot = (jnp.arange(c)[None, :] == cluster_ids[:, None])  # (H, C)
    onehot = onehot & should_update[:, None] & (cluster_ids >= 0)[:, None]
    w = jnp.asarray(onehot, state.reps.dtype)

    touched = jnp.any(onehot, axis=0)                          # (C,)
    upd_masks = jnp.einsum("hc,hij->cij", w,
                           jnp.asarray(new_masks, state.reps.dtype)) > 0.5
    upd_reps = jnp.einsum("hc,hn->cn", w, new_reps)

    masks = jnp.where(touched[:, None, None], upd_masks, state.masks)
    reps = jnp.where(touched[:, None], upd_reps, state.reps)
    valid = state.valid | touched
    return PivotalState(masks=masks, reps=reps, valid=valid)


def merge_across_devices(state: PivotalState) -> PivotalState:
    """No-op placeholder: under pjit the scatter/where above already carries
    the GSPMD-inserted all-reduce when heads are sharded over ``model``.
    Kept as an explicit extension point for shard_map-based variants."""
    return state
