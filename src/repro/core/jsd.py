"""Jensen-Shannon divergence utilities (paper §5.2, Algorithm 3 line 6).

The paper measures head sparsity and inter-head similarity with the
Jensen-Shannon *distance* ``√JSD(p‖q)``.  We use base-2 logarithms so the
divergence is bounded in [0, 1] and the distance in [0, 1] — matching the
convention of ``scipy.spatial.distance.jensenshannon`` the authors build on
and making the thresholds τ=0.2 / δ=0.3 scale-free.
"""
from __future__ import annotations

import jax.numpy as jnp

_EPS = 1e-12
_LN2 = 0.6931471805599453


def _kl(p: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """KL(p‖q) in bits along the last axis; p, q are probability vectors."""
    p = jnp.clip(p, _EPS, 1.0)
    q = jnp.clip(q, _EPS, 1.0)
    return jnp.sum(p * (jnp.log(p) - jnp.log(q)), axis=-1) / _LN2


def js_divergence(p: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """JSD(p‖q) ∈ [0, 1] (base-2) along the last axis."""
    m = 0.5 * (p + q)
    return 0.5 * _kl(p, m) + 0.5 * _kl(q, m)


def js_distance(p: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """√JSD(p‖q) — the metric used for d_sparse and d_sim."""
    return jnp.sqrt(jnp.maximum(js_divergence(p, q), 0.0))


def js_distance_to_uniform(p: jnp.ndarray) -> jnp.ndarray:
    """d_sparse = √JSD(p‖u) with u uniform over the support of the last axis."""
    n = p.shape[-1]
    u = jnp.full_like(p, 1.0 / n)
    return js_distance(p, u)


def normalize(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Project non-negative scores onto the simplex."""
    x = jnp.maximum(x, 0.0)
    s = jnp.sum(x, axis=axis, keepdims=True)
    return x / jnp.maximum(s, _EPS)
