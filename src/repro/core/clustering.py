"""Offline clustering of similar attention heads (paper §5.2 + Appendix A.4/C).

Pipeline (matches the paper, adapted to this container — DESIGN.md §8):

  1. capture block-averaged attention score maps for every (layer, head) from
     a profiling prefill on a retrieval-style sample;
  2. pool each map to a fixed POOLED×POOLED grid, embed with a small
     convolutional autoencoder (latent 64, paper Appendix C) trained in pure
     JAX with Adam (paper: PyTorch, lr 1e-3, early stopping);
  3. L2-normalize latents and run average-linkage agglomerative clustering
     with a distance threshold (paper: scipy ``fcluster``; ours is a numpy
     Lance-Williams implementation since scipy is unavailable offline);
  4. clusters smaller than ``min_cluster_size`` become the noise cluster (-1).

The result is the static ``head_dict``: an (L, H) int32 array of cluster ids.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

POOLED = 32          # pooled attention-map side fed to the autoencoder
LATENT = 64          # paper Appendix A.4: latent dimension 64


# --------------------------------------------------------------------------
# Attention-map preprocessing
# --------------------------------------------------------------------------

def pool_map(score_map: jnp.ndarray, out: int = POOLED) -> jnp.ndarray:
    """Average-pool an (NB, NB) block score map to (out, out)."""
    nb = score_map.shape[-1]
    if nb < out:
        reps = -(-out // nb)
        score_map = jnp.repeat(jnp.repeat(score_map, reps, -2), reps, -1)
        nb = score_map.shape[-1]
    crop = (nb // out) * out
    x = score_map[..., :crop, :crop]
    x = x.reshape(*x.shape[:-2], out, crop // out, out, crop // out)
    return x.mean(axis=(-3, -1))


def binarize_maps(maps: jnp.ndarray, gamma: float = 0.9) -> jnp.ndarray:
    """Threshold pooled maps to [0,1] (patterns, not magnitudes, cluster)."""
    flat = maps.reshape(maps.shape[0], -1)
    mx = jnp.max(flat, axis=-1, keepdims=True)
    return (flat / jnp.maximum(mx, 1e-12)).reshape(maps.shape)


# --------------------------------------------------------------------------
# Convolutional autoencoder (paper Appendix C, scaled to POOLED×POOLED input)
# --------------------------------------------------------------------------

AEParams = dict     # pytree of autoencoder weights (paper Appendix C)


def init_autoencoder(key: jax.Array, pooled: int = POOLED) -> AEParams:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p4 = pooled // 4
    flat = 32 * p4 * p4
    s = lambda *sh: 1.0 / np.sqrt(np.prod(sh[:-1]) + 1.0)
    return dict(
        conv1=jax.random.normal(k1, (3, 3, 1, 16)) * 0.1,
        conv2=jax.random.normal(k2, (3, 3, 16, 32)) * 0.1,
        enc_w=jax.random.normal(k3, (flat, LATENT)) * s(flat, LATENT),
        enc_b=jnp.zeros((LATENT,)),
        dec_w=jax.random.normal(k4, (LATENT, pooled * pooled)) * s(LATENT, 1),
        dec_b=jnp.zeros((pooled * pooled,)),
    )


def _conv(x, w):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def encode(params: AEParams, maps: jnp.ndarray) -> jnp.ndarray:
    """(M, P, P) pooled maps → (M, LATENT) embeddings."""
    x = maps[..., None]                       # NHWC
    x = jax.nn.relu(_conv(x, params["conv1"]))
    x = _maxpool2(x)
    x = jax.nn.relu(_conv(x, params["conv2"]))
    x = _maxpool2(x)
    x = x.reshape(x.shape[0], -1)
    return x @ params["enc_w"] + params["enc_b"]


def decode(params: AEParams, z: jnp.ndarray, pooled: int = POOLED):
    x = jax.nn.sigmoid(z @ params["dec_w"] + params["dec_b"])
    return x.reshape(-1, pooled, pooled)


def train_autoencoder(maps: jnp.ndarray, *, epochs: int = 300,
                      lr: float = 1e-3, seed: int = 0,
                      patience: int = 30) -> AEParams:
    """MSE reconstruction training with Adam + early stopping (paper A.4)."""
    pooled = maps.shape[-1]
    params = init_autoencoder(jax.random.PRNGKey(seed), pooled)
    flat, treedef = jax.tree.flatten(params)
    m = [jnp.zeros_like(p) for p in flat]
    v = [jnp.zeros_like(p) for p in flat]

    def loss_fn(params):
        z = encode(params, maps)
        recon = decode(params, z, pooled)
        return jnp.mean((recon - maps) ** 2)

    grad_fn = jax.jit(jax.value_and_grad(
        lambda leaves: loss_fn(jax.tree.unflatten(treedef, leaves))))

    best, since_best = np.inf, 0
    b1, b2, eps = 0.9, 0.999, 1e-8
    for t in range(1, epochs + 1):
        loss, g = grad_fn(flat)
        m = [b1 * mi + (1 - b1) * gi for mi, gi in zip(m, g)]
        v = [b2 * vi + (1 - b2) * gi**2 for vi, gi in zip(v, g)]
        mh = [mi / (1 - b1**t) for mi in m]
        vh = [vi / (1 - b2**t) for vi in v]
        flat = [p - lr * mi / (jnp.sqrt(vi) + eps)
                for p, mi, vi in zip(flat, mh, vh)]
        lv = float(loss)
        if lv < best - 1e-6:
            best, since_best = lv, 0
        else:
            since_best += 1
            if since_best >= patience:
                break
    return jax.tree.unflatten(treedef, flat)


# --------------------------------------------------------------------------
# Average-linkage agglomerative clustering (numpy; scipy unavailable)
# --------------------------------------------------------------------------

def agglomerative_cluster(x: np.ndarray, distance_threshold: float
                          ) -> np.ndarray:
    """Average-linkage clustering; merge while min inter-cluster dist < thr.

    Lance-Williams update for average linkage:
        d(k, i∪j) = (n_i d(k,i) + n_j d(k,j)) / (n_i + n_j)
    Returns integer labels (0..K-1).
    """
    n = x.shape[0]
    d = np.sqrt(np.maximum(
        ((x[:, None, :] - x[None, :, :]) ** 2).sum(-1), 0.0))
    np.fill_diagonal(d, np.inf)
    sizes = np.ones(n)
    alive = np.ones(n, dtype=bool)
    members: list[list[int]] = [[i] for i in range(n)]

    while alive.sum() > 1:
        sub = np.where(alive)[0]
        dd = d[np.ix_(sub, sub)]
        flat = np.argmin(dd)
        a, b = divmod(flat, dd.shape[1])
        i, j = sub[a], sub[b]
        if d[i, j] >= distance_threshold:
            break
        # merge j into i
        ni, nj = sizes[i], sizes[j]
        newrow = (ni * d[i] + nj * d[j]) / (ni + nj)
        d[i, :] = newrow
        d[:, i] = newrow
        d[i, i] = np.inf
        d[j, :] = np.inf
        d[:, j] = np.inf
        sizes[i] = ni + nj
        alive[j] = False
        members[i].extend(members[j])
        members[j] = []

    labels = np.full(n, -1, dtype=np.int32)
    k = 0
    for i in range(n):
        if alive[i]:
            for idx in members[i]:
                labels[idx] = k
            k += 1
    return labels


# --------------------------------------------------------------------------
# End-to-end head clustering
# --------------------------------------------------------------------------

@dataclasses.dataclass
class ClusteringResult:
    cluster_ids: np.ndarray      # (L, H) int32, -1 = noise
    num_clusters: int
    latents: np.ndarray          # (L*H, LATENT) for diagnostics

    def cluster_ids_for_layer(self, layer: int) -> np.ndarray:
        return self.cluster_ids[layer]


def cluster_heads(score_maps: jnp.ndarray, *,
                  distance_threshold: float | None = None,
                  min_cluster_size: int = 5,
                  ae_epochs: int = 300,
                  seed: int = 0) -> ClusteringResult:
    """score_maps: (L, H, NB, NB) block-avg attention from a profiling run.

    ``distance_threshold=None`` picks it adaptively: the 25th percentile of
    the pairwise latent distances — similar heads merge, the spread tail
    stays apart (the paper hand-tunes 10 on unnormalized latents; an
    absolute value does not transfer across models, an order statistic does).
    """
    l, h = score_maps.shape[:2]
    flat_maps = score_maps.reshape(l * h, *score_maps.shape[2:])
    pooled = pool_map(flat_maps)
    pooled = binarize_maps(pooled)
    params = train_autoencoder(pooled, epochs=ae_epochs, seed=seed)
    z = np.asarray(encode(params, pooled))
    z = z / np.maximum(np.linalg.norm(z, axis=-1, keepdims=True), 1e-12)
    if distance_threshold is None:
        d = np.sqrt(np.maximum(
            ((z[:, None, :] - z[None, :, :]) ** 2).sum(-1), 0.0))
        off = d[~np.eye(len(z), dtype=bool)]
        distance_threshold = float(np.percentile(off, 25.0))
    labels = agglomerative_cluster(z, distance_threshold)

    # small clusters → noise (paper A.4: clusters with < 5 samples)
    out = labels.copy()
    k = 0
    for lbl in np.unique(labels):
        idx = labels == lbl
        if idx.sum() < min_cluster_size:
            out[idx] = -1
        else:
            out[idx] = k
            k += 1
    return ClusteringResult(
        cluster_ids=out.reshape(l, h).astype(np.int32),
        num_clusters=max(k, 1),
        latents=z)


def jaccard_similarity_matrix(masks: np.ndarray) -> np.ndarray:
    """Paper Figure 2(b): Jaccard (# intersection / # union) between head
    patterns.  masks: (M, NB, NB) bool."""
    m = masks.reshape(masks.shape[0], -1).astype(np.float64)
    inter = m @ m.T
    sums = m.sum(axis=1)
    union = sums[:, None] + sums[None, :] - inter
    return inter / np.maximum(union, 1.0)
