"""Sparse-pattern determination (paper Algorithm 3).

For each head, estimate the block-averaged attention distribution of the last
query block,

    â = softmax( pool(Q̂ Kᵀ) / √d ),      Q̂ = Q[-block_size:]

then compute

    d_sparse = √JSD(â ‖ u)     (vs the uniform distribution)
    d_sim    = √JSD(â ‖ ã)     (vs the cluster's pivotal representative)

and pick the pattern source:

    shared_pivot    if d_sparse < δ ∧ d_sim < τ ∧ pivot exists
    dense           if d_sparse < δ ∧ no pivot yet ∧ head is the cluster's
                    first head in this layer (Algorithm 4's "assign dense")
    vertical_slash  otherwise (incl. noise clusters and highly sparse heads)

Outputs are arithmetic selectors (no control flow) so the whole prefill stays
one jitted program (DESIGN.md §4).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.jsd import js_distance, js_distance_to_uniform

# Pattern-source codes (also used by benchmarks/bench_pattern_dist.py).
PATTERN_SHARED = 0
PATTERN_DENSE = 1
PATTERN_VERTICAL_SLASH = 2


class PatternDecision(NamedTuple):
    use_shared: jnp.ndarray     # (H,) bool
    use_dense: jnp.ndarray      # (H,) bool
    use_vs: jnp.ndarray         # (H,) bool
    a_hat_blocks: jnp.ndarray   # (H, NB) estimated block-avg attention â
    d_sparse: jnp.ndarray       # (H,)
    d_sim: jnp.ndarray          # (H,)


def pooled_block_estimate(strip: jnp.ndarray, block_size: int) -> jnp.ndarray:
    """â from a (b, N) softmaxed strip: mean over rows, sum within kv blocks.

    The paper pools Q̂Kᵀ logits then softmaxes; pooling *probabilities* per
    block is equivalent up to the softmax temperature of in-block variance and
    is numerically safer with −inf causal entries.  Both reduce to a (NB,)
    distribution over kv blocks.
    """
    b, n = strip.shape
    nb = n // block_size
    per_block = jnp.sum(strip.reshape(b, nb, block_size), axis=-1)
    a_hat = jnp.mean(per_block, axis=0)
    return a_hat / jnp.maximum(jnp.sum(a_hat), 1e-12)


def first_head_in_cluster(cluster_ids: jnp.ndarray) -> jnp.ndarray:
    """(H,) bool: head is the lowest-indexed head of its cluster in the layer."""
    eq = cluster_ids[:, None] == cluster_ids[None, :]
    first_idx = jnp.argmax(eq, axis=1)      # first True along the row
    return jnp.arange(cluster_ids.shape[0]) == first_idx


def determine_sparse_pattern(
    a_hat_blocks: jnp.ndarray,      # (H, NB) â per head
    cluster_ids: jnp.ndarray,       # (H,) int32, -1 = noise
    pivot_reps: jnp.ndarray,        # (H, NB) ã gathered per head
    pivot_valid: jnp.ndarray,       # (H,) bool pivot exists for head's cluster
    *,
    delta: float,
    tau: float,
) -> PatternDecision:
    """Algorithm 3, vectorized over heads."""
    d_sparse = js_distance_to_uniform(a_hat_blocks)
    d_sim = js_distance(a_hat_blocks, pivot_reps)

    noise = cluster_ids < 0
    not_sparse = d_sparse < delta
    similar = d_sim < tau
    first = first_head_in_cluster(cluster_ids)

    use_shared = not_sparse & similar & pivot_valid & ~noise
    use_dense = not_sparse & ~pivot_valid & first & ~noise
    use_vs = ~(use_shared | use_dense)
    return PatternDecision(use_shared, use_dense, use_vs,
                           a_hat_blocks, d_sparse, d_sim)
