"""SharePrefill online orchestration (paper Algorithm 1, per layer).

For a single sample and one layer's heads:

  1. estimate â per head from the last-query-block strip (Algorithm 3);
  2. look up the cluster's pivotal pattern / representative (Algorithm 4);
  3. decide shared_pivot / dense / vertical_slash per head;
  4. materialize block masks for all three sources and select arithmetically;
  5. run block-sparse attention → output O and block-avg QK logits Ã;
  6. heads that ran dense construct new pivots (Algorithm 2) and update the
     dictionary state.

The function is pure; the pivotal dictionary is threaded as a
:class:`PivotalState` carry through the model's ``lax.scan`` over layers.

GQA is native end-to-end: K/V stay ``(Hkv, N, D)`` — the strip estimation
vmaps per kv-head group and the sparse kernel resolves ``h // group`` in its
BlockSpec index_map, so the ``H/Hkv`` redundant K/V copies the old
``jnp.repeat`` expansion materialized are never built.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SharePrefillConfig
from repro.core import pattern_dict as pdict
from repro.core.construct import construct_pivotal_pattern
from repro.core.determine import determine_sparse_pattern, pooled_block_estimate
from repro.core.patterns import block_mask_density, causal_block_mask
from repro.core.vertical_slash import search_vertical_slash_from_strip
from repro.kernels import compute_strips, sparse_attention_fn
from repro.kernels.ops import gqa_head_vmap  # noqa: F401 (public re-export)

# attention_fn: (q (H,N,D), k (Hkv,N,D), v (Hkv,N,Dv), mask (H,NB,NB))
#               -> (out (H,N,Dv), a_tilde (H,NB,NB))
# K/V arrive un-expanded; implementations either consume the GQA grouping
# natively (the Pallas kernel) or expand internally (the chunked fallback).
AttentionFn = Callable[..., Tuple[jnp.ndarray, jnp.ndarray]]


class LayerStats(NamedTuple):
    """Per-layer pattern statistics (paper Figure 6 / latency accounting)."""

    num_shared: jnp.ndarray     # scalar f32
    num_dense: jnp.ndarray
    num_vs: jnp.ndarray
    block_density: jnp.ndarray  # computed fraction of causal blocks (mean over heads)
    d_sparse_mean: jnp.ndarray
    d_sim_mean: jnp.ndarray


def share_prefill_attention_layer(
    q: jnp.ndarray,                 # (H, N, D)
    k: jnp.ndarray,                 # (Hkv, N, D) — un-expanded GQA heads
    v: jnp.ndarray,                 # (Hkv, N, D)
    state: pdict.PivotalState,
    cluster_ids: jnp.ndarray,       # (H,) int32, -1 = noise
    cfg: SharePrefillConfig,
    attention_fn: Optional[AttentionFn] = None,
    extra_mask: jnp.ndarray | None = None,  # (NB, NB) e.g. sliding window
    strip_impl: str = "auto",       # auto | pallas | jnp (Algorithm-3 pass)
) -> Tuple[jnp.ndarray, pdict.PivotalState, LayerStats]:
    h, n, d = q.shape
    bs = cfg.block_size
    nb = n // bs
    if attention_fn is None:
        attention_fn = sparse_attention_fn(block_size=bs)

    # -- Algorithm 3: estimate + decide ------------------------------------
    strips = compute_strips(q, k, block_size=bs, impl=strip_impl)
    a_hat = jax.vmap(lambda s: pooled_block_estimate(s, bs))(strips)

    pivot_masks, pivot_reps, pivot_valid = pdict.lookup(state, cluster_ids)
    decision = determine_sparse_pattern(
        a_hat, cluster_ids, pivot_reps, pivot_valid,
        delta=cfg.delta, tau=cfg.tau)

    # -- Algorithm 5 fallback ----------------------------------------------
    vs_masks = jax.vmap(
        lambda s: search_vertical_slash_from_strip(s, cfg.gamma, bs))(strips)

    # -- Algorithm 4: select mask source ------------------------------------
    causal = causal_block_mask(nb)
    masks = jnp.where(decision.use_shared[:, None, None], pivot_masks,
                      vs_masks)
    masks = jnp.where(decision.use_dense[:, None, None], causal[None], masks)
    masks = masks & causal[None]
    if extra_mask is not None:
        masks = masks & extra_mask[None]

    # -- sparse attention + Ã (Algorithm 1 line 8) ---------------------------
    out, a_tilde = attention_fn(q, k, v, masks)

    # -- Algorithm 2: construct + update dictionary --------------------------
    new_masks, new_reps = jax.vmap(
        lambda a: construct_pivotal_pattern(a, cfg.gamma))(a_tilde)
    new_state = pdict.update(state, cluster_ids, new_masks, new_reps,
                             decision.use_dense)

    stats = LayerStats(
        num_shared=jnp.sum(decision.use_shared.astype(jnp.float32)),
        num_dense=jnp.sum(decision.use_dense.astype(jnp.float32)),
        num_vs=jnp.sum(decision.use_vs.astype(jnp.float32)),
        block_density=jnp.mean(block_mask_density(masks)),
        d_sparse_mean=jnp.mean(decision.d_sparse),
        d_sim_mean=jnp.mean(decision.d_sim),
    )
    return out, new_state, stats


def batched_share_prefill_attention_layer(
    q: jnp.ndarray,                 # (B, H, N, D)
    k: jnp.ndarray,                 # (B, Hkv, N, D) — un-expanded GQA heads
    v: jnp.ndarray,
    state: pdict.PivotalState,      # batched: leaves carry leading B dim
    cluster_ids: jnp.ndarray,       # (H,)
    cfg: SharePrefillConfig,
    attention_fn: Optional[AttentionFn] = None,
    extra_mask: jnp.ndarray | None = None,
    strip_impl: str = "auto",
) -> Tuple[jnp.ndarray, pdict.PivotalState, LayerStats]:
    """vmap over the batch; each sample carries its own pattern dictionary
    (patterns are input-dependent — paper observation 2 is about *similarity
    structure*, not the patterns themselves)."""
    fn = lambda qb, kb, vb, st: share_prefill_attention_layer(
        qb, kb, vb, st, cluster_ids, cfg, attention_fn, extra_mask,
        strip_impl)
    out, new_state, stats = jax.vmap(fn)(q, k, v, state)
    stats = jax.tree.map(jnp.mean, stats)
    return out, new_state, stats


def init_batched_state(batch: int, num_clusters: int,
                       nb: int) -> pdict.PivotalState:
    st = pdict.init_pivotal_state(num_clusters, nb)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (batch,) + x.shape), st)
