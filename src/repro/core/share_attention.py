"""SharePrefill online orchestration (paper Algorithm 1, per layer).

For a single sample and one layer's heads:

  1. estimate â per head from the last-query-block strip (Algorithm 3);
  2. look up the cluster's pivotal pattern / representative (Algorithm 4);
  3. decide shared_pivot / dense / vertical_slash per head;
  4. materialize block masks for all three sources and select arithmetically;
  5. run block-sparse attention → output O and block-avg QK logits Ã;
  6. heads that ran dense construct new pivots (Algorithm 2) and update the
     dictionary state.

The function is pure; the pivotal dictionary is threaded as a
:class:`PivotalState` carry through the model's ``lax.scan`` over layers.
The flow is split into composable stages — :func:`build_share_masks` (1-4),
the attention backend (5), :func:`update_share_state` (6) — so the batched
wrapper can vmap the cheap mask logic per sample while issuing **one**
batch-native kernel call for step 5.

GQA is native end-to-end: K/V stay ``(Hkv, N, D)`` — the strip estimation
vmaps per kv-head group and the sparse kernel resolves ``h // group`` in its
BlockSpec index_map, so the ``H/Hkv`` redundant K/V copies the old
``jnp.repeat`` expansion materialized are never built.

Batched vs per-sample attention backends
----------------------------------------
An ``attention_fn`` carrying ``fn.batched = True`` (e.g.
:func:`repro.kernels.batched_sparse_attention_fn`) consumes the whole batch
at once — ``(B, H, N, D)`` q, ``(B, Hkv, N, D)`` K/V, ``(B, H, NB, NB)``
masks, plus an optional ``stats_gate`` — and
:func:`batched_share_prefill_attention_layer` hoists it out of the
per-sample ``jax.vmap``, additionally permuting heads within each GQA group
so heads sharing a pivotal pattern are grid-adjacent
(:func:`pattern_sharing_head_perm`) and gating the fused Ã stats to the
dense-construction heads.  Plain per-sample AttentionFns keep the legacy
vmap-the-whole-layer path.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SharePrefillConfig
from repro.core import pattern_dict as pdict
from repro.core.construct import construct_pivotal_pattern
from repro.core.determine import (
    PatternDecision,
    determine_sparse_pattern,
    pooled_block_estimate,
)
from repro.core.patterns import block_mask_density, causal_block_mask
from repro.core.vertical_slash import search_vertical_slash_from_strip
from repro.kernels import (
    batched_sparse_attention_fn,
    compute_strips,
    sparse_attention_fn,
)
from repro.kernels.ops import gqa_head_vmap  # noqa: F401 (public re-export)

# attention_fn (per-sample): (q (H,N,D), k (Hkv,N,D), v (Hkv,N,Dv),
#               mask (H,NB,NB)) -> (out (H,N,Dv), a_tilde (H,NB,NB))
# attention_fn (batched, fn.batched=True): leading B on q/k/v/mask, optional
#               stats_gate=(B,H) kwarg — see module docstring.
# K/V arrive un-expanded; implementations either consume the GQA grouping
# natively (the Pallas kernels) or expand internally (the chunked fallback).
AttentionFn = Callable[..., Tuple[jnp.ndarray, jnp.ndarray]]


class LayerStats(NamedTuple):
    """Per-layer pattern statistics (paper Figure 6 / latency accounting)."""

    num_shared: jnp.ndarray     # scalar f32
    num_dense: jnp.ndarray
    num_vs: jnp.ndarray
    block_density: jnp.ndarray  # computed fraction of causal blocks (mean over heads)
    d_sparse_mean: jnp.ndarray
    d_sim_mean: jnp.ndarray
    max_row_pop: jnp.ndarray    # max kept blocks in any (head, q-block) row
                                # — the count-aware width policy's observable


def build_share_masks(
    q: jnp.ndarray,                 # (H, N, D)
    k: jnp.ndarray,                 # (Hkv, N, D)
    state: pdict.PivotalState,
    cluster_ids: jnp.ndarray,       # (H,)
    cfg: SharePrefillConfig,
    extra_mask: jnp.ndarray | None = None,
    strip_impl: str = "auto",
) -> Tuple[jnp.ndarray, PatternDecision]:
    """Algorithm 3-5 mask staging for one sample: estimate, decide, and
    materialize the selected per-head block masks (causal ∧ extra applied).

    Returns ``(masks (H, NB, NB), decision)``.
    """
    bs = cfg.block_size
    n = q.shape[1]
    nb = n // bs

    # -- Algorithm 3: estimate + decide ------------------------------------
    strips = compute_strips(q, k, block_size=bs, impl=strip_impl)
    a_hat = jax.vmap(lambda s: pooled_block_estimate(s, bs))(strips)

    pivot_masks, pivot_reps, pivot_valid = pdict.lookup(state, cluster_ids)
    decision = determine_sparse_pattern(
        a_hat, cluster_ids, pivot_reps, pivot_valid,
        delta=cfg.delta, tau=cfg.tau)

    # -- Algorithm 5 fallback ----------------------------------------------
    vs_masks = jax.vmap(
        lambda s: search_vertical_slash_from_strip(s, cfg.gamma, bs))(strips)

    # -- Algorithm 4: select mask source ------------------------------------
    causal = causal_block_mask(nb)
    masks = jnp.where(decision.use_shared[:, None, None], pivot_masks,
                      vs_masks)
    masks = jnp.where(decision.use_dense[:, None, None], causal[None], masks)
    masks = masks & causal[None]
    if extra_mask is not None:
        masks = masks & extra_mask[None]
    return masks, decision


def update_share_state(
    a_tilde: jnp.ndarray,           # (H, NB, NB) scattered kernel stats
    state: pdict.PivotalState,
    cluster_ids: jnp.ndarray,
    decision: PatternDecision,
    cfg: SharePrefillConfig,
) -> pdict.PivotalState:
    """Algorithm 2: dense-construction heads build pivots and update the
    dictionary.  Only ``decision.use_dense`` heads' constructions are kept,
    so Ã rows of shared/VS heads may be arbitrary (e.g. all −inf when the
    kernel's stats gating skipped them)."""
    new_masks, new_reps = jax.vmap(
        lambda a: construct_pivotal_pattern(a, cfg.gamma))(a_tilde)
    return pdict.update(state, cluster_ids, new_masks, new_reps,
                        decision.use_dense)


def pattern_sharing_head_perm(decision: PatternDecision,
                              cluster_ids: jnp.ndarray,
                              group: int) -> jnp.ndarray:
    """Schedule-level pattern sharing: a head permutation making heads that
    share a pivotal pattern adjacent *within their GQA group*.

    Adjacent heads with identical index rows re-address the same
    ``(kv_head, block)`` K/V tile on consecutive steps of the batched
    kernel's innermost head axis, so the Pallas TPU pipeline elides their
    DMAs — the paper's pattern sharing exploited at the schedule level, not
    just the mask level.  Staying within the group keeps ``h // group``
    (the kv-head binding) invariant.  Non-shared heads keep their relative
    order; the sort is stable, so the permutation is the identity whenever
    no two heads of a group share a cluster pivot.

    Returns ``perm (H,)`` int32: position p of the kernel schedule runs
    original head ``perm[p]``.  Invert with ``jnp.argsort(perm)``.
    """
    h = cluster_ids.shape[0]
    hkv = h // group
    # shared heads sort by cluster (equal keys → adjacent); everyone else
    # keeps original order behind a large offset
    key = jnp.where(decision.use_shared, cluster_ids,
                    (1 << 30) + jnp.arange(h, dtype=jnp.int32))
    order = jnp.argsort(key.reshape(hkv, group), axis=1, stable=True)
    base = (jnp.arange(hkv, dtype=jnp.int32) * group)[:, None]
    return (base + order).reshape(h).astype(jnp.int32)


def layer_pattern_stats(masks: jnp.ndarray, decision: PatternDecision
                 ) -> LayerStats:
    """LayerStats from (…, H, NB, NB) masks and a (…, H) decision — works
    for one sample or a batch (leading axes are averaged; max_row_pop is a
    max, it feeds the count-aware width policy)."""
    f32 = lambda x: x.astype(jnp.float32)
    count = lambda flag: jnp.mean(jnp.sum(f32(flag), axis=-1))
    return LayerStats(
        num_shared=count(decision.use_shared),
        num_dense=count(decision.use_dense),
        num_vs=count(decision.use_vs),
        block_density=jnp.mean(block_mask_density(masks)),
        d_sparse_mean=jnp.mean(decision.d_sparse),
        d_sim_mean=jnp.mean(decision.d_sim),
        max_row_pop=jnp.max(jnp.sum(f32(masks), axis=-1)),
    )


def share_prefill_attention_layer(
    q: jnp.ndarray,                 # (H, N, D)
    k: jnp.ndarray,                 # (Hkv, N, D) — un-expanded GQA heads
    v: jnp.ndarray,                 # (Hkv, N, D)
    state: pdict.PivotalState,
    cluster_ids: jnp.ndarray,       # (H,) int32, -1 = noise
    cfg: SharePrefillConfig,
    attention_fn: Optional[AttentionFn] = None,
    extra_mask: jnp.ndarray | None = None,  # (NB, NB) e.g. sliding window
    strip_impl: str = "auto",       # auto | pallas | jnp (Algorithm-3 pass)
) -> Tuple[jnp.ndarray, pdict.PivotalState, LayerStats]:
    if attention_fn is None:
        attention_fn = sparse_attention_fn(block_size=cfg.block_size)

    masks, decision = build_share_masks(q, k, state, cluster_ids, cfg,
                                        extra_mask, strip_impl)

    # -- sparse attention + Ã (Algorithm 1 line 8) ---------------------------
    if getattr(attention_fn, "batched", False):
        out, a_tilde = attention_fn(q[None], k[None], v[None], masks[None],
                                    stats_gate=decision.use_dense[None])
        out, a_tilde = out[0], a_tilde[0]
    else:
        out, a_tilde = attention_fn(q, k, v, masks)

    # -- Algorithm 2: construct + update dictionary --------------------------
    new_state = update_share_state(a_tilde, state, cluster_ids, decision,
                                   cfg)
    return out, new_state, layer_pattern_stats(masks, decision)


def batched_share_prefill_attention_layer(
    q: jnp.ndarray,                 # (B, H, N, D)
    k: jnp.ndarray,                 # (B, Hkv, N, D) — un-expanded GQA heads
    v: jnp.ndarray,
    state: pdict.PivotalState,      # batched: leaves carry leading B dim
    cluster_ids: jnp.ndarray,       # (H,)
    cfg: SharePrefillConfig,
    attention_fn: Optional[AttentionFn] = None,
    extra_mask: jnp.ndarray | None = None,
    strip_impl: str = "auto",
    reorder_heads: bool = True,
) -> Tuple[jnp.ndarray, pdict.PivotalState, LayerStats]:
    """One layer of SharePrefill over a batch; each sample carries its own
    pattern dictionary (patterns are input-dependent — paper observation 2
    is about *similarity structure*, not the patterns themselves).

    With a batched ``attention_fn`` (``fn.batched``, the default) the mask
    staging and dictionary update are vmapped per sample but the kernel
    runs ONCE on the whole batch — a ``(B, T, H)`` grid with per-(batch,
    head) scalar-prefetched tables — with heads permuted per sample so
    shared-pattern heads are grid-adjacent (``reorder_heads``; outputs and
    Ã are unpermuted before the dictionary update, so results are invariant
    to the reorder).  A per-sample ``attention_fn`` falls back to vmapping
    the whole layer.
    """
    if attention_fn is None:
        attention_fn = batched_sparse_attention_fn(block_size=cfg.block_size)

    if not getattr(attention_fn, "batched", False):
        fn = lambda qb, kb, vb, st: share_prefill_attention_layer(
            qb, kb, vb, st, cluster_ids, cfg, attention_fn, extra_mask,
            strip_impl)
        out, new_state, stats = jax.vmap(fn)(q, k, v, state)
        return out, new_state, _reduce_layer_stats(stats)

    group = q.shape[1] // k.shape[1]
    masks, decision = jax.vmap(
        lambda qb, kb, st: build_share_masks(qb, kb, st, cluster_ids, cfg,
                                             extra_mask, strip_impl)
    )(q, k, state)
    gate = decision.use_dense                            # (B, H)

    if reorder_heads:
        perm = jax.vmap(
            lambda d: pattern_sharing_head_perm(d, cluster_ids, group)
        )(decision)                                      # (B, H)
        take = lambda x, p: jnp.take_along_axis(
            x, p.reshape(p.shape + (1,) * (x.ndim - 2)), axis=1)
        out_p, a_p = attention_fn(take(q, perm), k, v, take(masks, perm),
                                  stats_gate=take(gate, perm))
        inv = jnp.argsort(perm, axis=1)
        out, a_tilde = take(out_p, inv), take(a_p, inv)
    else:
        out, a_tilde = attention_fn(q, k, v, masks, stats_gate=gate)

    new_state = jax.vmap(
        lambda a, st, d: update_share_state(a, st, cluster_ids, d, cfg)
    )(a_tilde, state, decision)
    return out, new_state, layer_pattern_stats(masks, decision)


def _reduce_layer_stats(stats: LayerStats) -> LayerStats:
    """Reduce vmapped per-sample LayerStats over the batch: means, except
    ``max_row_pop`` (a bound — the max over samples)."""
    means = LayerStats(*(jnp.mean(f) for f in stats))
    return means._replace(max_row_pop=jnp.max(stats.max_row_pop))


def init_batched_state(batch: int, num_clusters: int,
                       nb: int) -> pdict.PivotalState:
    st = pdict.init_pivotal_state(num_clusters, nb)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (batch,) + x.shape), st)
