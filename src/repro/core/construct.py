"""Pivotal pattern construction (paper Algorithm 2).

Given the block-averaged QK logits Ã emitted by the sparse attention kernel
for a head that ran **dense** attention, construct the pivotal pattern:

  1. row-softmax Ã over kv blocks → block-averaged attention scores;
  2. the last row becomes the pivotal representative ã;
  3. flatten, normalize, and select the minimal block set with cumulative
     mass ≥ γ → pivotal mask M.

Skipped / non-causal blocks carry ``-inf`` in Ã and therefore zero mass.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.core.patterns import cumulative_topk_mask


def block_softmax(a_tilde: jnp.ndarray) -> jnp.ndarray:
    """Row-wise softmax over kv blocks; rows with no valid block become 0."""
    row_max = jnp.max(a_tilde, axis=-1, keepdims=True)
    safe_max = jnp.where(jnp.isfinite(row_max), row_max, 0.0)
    e = jnp.where(jnp.isfinite(a_tilde), jnp.exp(a_tilde - safe_max), 0.0)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    return e / jnp.maximum(denom, 1e-12)


def construct_pivotal_pattern(
    a_tilde: jnp.ndarray, gamma: float
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Algorithm 2 for one head.

    Args:
      a_tilde: (NB, NB) block-averaged QK logits (−inf on skipped blocks).
      gamma: cumulative attention threshold.

    Returns:
      (mask, rep): (NB, NB) bool pivotal pattern and (NB,) f32 representative
      ã (the block-averaged attention of the last query-block row).
    """
    scores = block_softmax(jnp.asarray(a_tilde, jnp.float32))
    rep = scores[-1, :]
    nb = scores.shape[-1]
    flat = scores.reshape(-1)
    keep = cumulative_topk_mask(flat, gamma)
    mask = keep.reshape(nb, nb)
    # Guarantee a well-defined softmax for every query row: keep the block
    # diagonal (each query row's local block is always computed).
    diag = jnp.eye(nb, dtype=bool)
    return mask | diag, rep
