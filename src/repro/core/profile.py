"""Profiling utilities for the offline phase and the paper's analyses.

``capture_block_attention_maps`` runs a dense prefill over a decoder-only
GQA transformer and records the block-averaged attention score map of every
(layer, head) — the input to offline clustering (paper §5.2: "clustering on
their attention score maps using a sample from the Retr.KV task").

``run_prefill_traced`` runs the SharePrefill flow layer-by-layer in Python
(same math as the jitted scan) and records per-layer pattern statistics and
masks — the data behind Figure 2 (observations) and Figure 6 (pattern
distribution).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import pattern_dict as pdict
from repro.core.api import SharePrefill
from repro.core.construct import block_softmax
from repro.core.share_attention import (
    build_share_masks,
    gqa_head_vmap,
    layer_pattern_stats,
    share_prefill_attention_layer,
    update_share_state,
)
from repro.kernels.chunked import chunked_attention, chunked_attention_fn
from repro.models import common
from repro.models.transformer import (
    embed_tokens,
    logits_from_hidden,
    num_prefix_layers,
)


def _layer_slice(stack, l: int):
    return jax.tree.map(lambda p: p[l], stack)


def _layer_qkv(layer, x, cfg: ModelConfig, positions):
    from repro.models.attention import rope_qk
    h = common.rmsnorm(layer["ln1"], x, cfg.rms_norm_eps)
    q, k, v = common.gqa_qkv(layer["attn"], h)
    q, k = rope_qk(q, k, positions, cfg)
    return q, k, v


def _layer_finish(layer, x, attn_out, cfg: ModelConfig, moe_ffn: bool):
    x = x + common.gqa_out(layer["attn"], attn_out)
    h = common.rmsnorm(layer["ln2"], x, cfg.rms_norm_eps)
    if moe_ffn:
        from repro.models.moe import moe_apply
        y, _ = moe_apply(layer["ffn"], h, cfg)
    else:
        y = common.mlp(layer["ffn"], h)
    return x + y


def capture_block_attention_maps(params, cfg: ModelConfig,
                                 tokens: jnp.ndarray, *,
                                 block_size: int = 64
                                 ) -> np.ndarray:
    """Dense prefill capturing block-avg attention maps.

    tokens: (1, S).  Returns (L, H, NB, NB) float32 row-softmaxed maps.
    Supports the dense/vlm/moe transformer families.
    """
    b, s = tokens.shape
    assert b == 1, "profiling uses a single sample (paper §5.2)"
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x = embed_tokens(params, cfg, tokens)
    moe_ffn = cfg.moe.enabled
    maps: List[np.ndarray] = []
    n_prefix = num_prefix_layers(cfg)

    layers = ([params[f"prefix_{i}"] for i in range(n_prefix)]
              + [_layer_slice(params["stack"], l)
                 for l in range(cfg.num_layers - n_prefix)])
    for li, layer in enumerate(layers):
        q, k, v = _layer_qkv(layer, x, cfg, positions)
        kx = common.repeat_kv(k, cfg.gqa_groups)
        vx = common.repeat_kv(v, cfg.gqa_groups)
        out, a_tilde = chunked_attention(
            q, kx, vx, block_size=block_size, causal=True,
            collect_stats=True)
        maps.append(np.asarray(jax.vmap(block_softmax)(a_tilde[0])))
        x = _layer_finish(layer, x, out, cfg,
                          moe_ffn and li >= n_prefix)
    return np.stack(maps)           # (L, H, NB, NB)


@dataclasses.dataclass
class PrefillTrace:
    last_logits: np.ndarray
    full_logits: Optional[np.ndarray]
    per_layer: List[Dict[str, float]]       # shared/dense/vs/density per layer
    masks: List[np.ndarray]                 # (H, NB, NB) per layer
    qkv: List[Tuple[np.ndarray, np.ndarray, np.ndarray]]  # per layer, opt.


def run_prefill_traced(params, cfg: ModelConfig, tokens: jnp.ndarray,
                       sp: SharePrefill, *, method: str = "share",
                       want_full_logits: bool = False,
                       want_masks: bool = False,
                       want_qkv: bool = False) -> PrefillTrace:
    """Layer-by-layer SharePrefill prefill with per-layer statistics.

    ``want_masks`` records each layer's selected (H, NB, NB) block masks
    (all methods, including ``share``) — the input to count-aware width
    resolution; ``want_qkv`` additionally records each layer's un-expanded
    (q, k, v), which the latency benchmark's phase breakdown replays."""
    from repro.core import baselines
    from repro.core.patterns import block_mask_density, causal_block_mask

    b, s = tokens.shape
    assert b == 1
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x = embed_tokens(params, cfg, tokens)
    bs = sp.cfg.block_size
    nb = s // bs
    state = pdict.init_pivotal_state(max(sp.num_clusters, 1), nb)
    attention_fn = chunked_attention_fn(block_size=bs)
    n_prefix = num_prefix_layers(cfg)
    moe_ffn = cfg.moe.enabled

    per_layer, masks_out, qkv_out = [], [], []
    layers = ([params[f"prefix_{i}"] for i in range(n_prefix)]
              + [_layer_slice(params["stack"], l)
                 for l in range(cfg.num_layers - n_prefix)])
    for li, layer in enumerate(layers):
        # K/V stay un-expanded (Hkv heads) — masks are built per kv-head
        # group and every attention backend consumes the grouping natively
        q, k, v = _layer_qkv(layer, x, cfg, positions)
        h = q.shape[1]
        if method == "share":
            ids = jnp.asarray(sp.cluster_ids[li]) if sp.cfg.enabled else \
                jnp.arange(h, dtype=jnp.int32)
            # staged form of share_prefill_attention_layer so the selected
            # masks are observable (count-aware width resolution)
            mask, decision = build_share_masks(q[0], k[0], state, ids,
                                               sp.cfg)
            out, a_tilde = attention_fn(q[0], k[0], v[0], mask)
            state = update_share_state(a_tilde, state, ids, decision,
                                       sp.cfg)
            st = layer_pattern_stats(mask, decision)
            out = out[None]
            rec = {"num_shared": float(st.num_shared),
                   "num_dense": float(st.num_dense),
                   "num_vs": float(st.num_vs),
                   "block_density": float(st.block_density),
                   "max_row_pop": float(st.max_row_pop)}
        else:
            if method == "dense":
                mask = jnp.broadcast_to(causal_block_mask(nb)[None],
                                        (h, nb, nb))
            elif method == "vertical_slash":
                mask = gqa_head_vmap(
                    lambda qh, kh: baselines.minference_head_mask(
                        qh, kh, gamma=sp.cfg.gamma, block_size=bs),
                    q[0], k[0])
            elif method == "flex":
                mask = gqa_head_vmap(
                    lambda qh, kh: baselines.flexprefill_head_mask(
                        qh, kh, gamma=sp.cfg.gamma, block_size=bs),
                    q[0], k[0])
            else:
                raise ValueError(method)
            mask = mask & causal_block_mask(nb)[None]
            out, _ = attention_fn(q[0], k[0], v[0], mask)
            out = out[None]
            rec = {"num_shared": 0.0, "num_dense": 0.0,
                   "num_vs": float(h),
                   "block_density": float(
                       jnp.mean(block_mask_density(mask))),
                   "max_row_pop": float(jnp.max(jnp.sum(
                       mask.astype(jnp.float32), axis=-1)))}
        per_layer.append(rec)
        if want_masks and mask is not None:
            masks_out.append(np.asarray(mask))
        if want_qkv:
            qkv_out.append((np.asarray(q[0]), np.asarray(k[0]),
                            np.asarray(v[0])))
        x = _layer_finish(layer, x, out, cfg, moe_ffn and li >= n_prefix)

    full = logits_from_hidden(params, cfg, x) if want_full_logits else None
    last = logits_from_hidden(params, cfg, x[:, -1, :])
    return PrefillTrace(np.asarray(last),
                        None if full is None else np.asarray(full),
                        per_layer, masks_out, qkv_out)
