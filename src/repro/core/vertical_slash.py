"""Cumulative-threshold vertical-slash pattern search (paper Algorithm 5).

Faithful to FlexPrefill's search: a representative query strip Q̂ (the last
``block_size`` queries) scores every key; vertical (column) and slash
(diagonal) directions are summed, normalized, and the minimal sets covering
cumulative mass γ are selected.  TPU adaptation (DESIGN.md §3): the selected
*token* columns/diagonals are then quantized to 128-wide *block* columns /
block diagonals, and the union is expanded into a causal block mask.

Everything here operates on a single head; callers vmap over heads/batch.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.patterns import (
    cumulative_topk_mask,
    slash_block_mask,
    vertical_block_mask,
)
# strip_scores lives with its Pallas twin now (re-exported for back-compat);
# the kernels package must not depend on repro.core.
from repro.kernels.strip import strip_scores  # noqa: F401


def vertical_slash_direction_scores(a_hat: jnp.ndarray):
    """sum_vertical / sum_slash of a (b, N) strip of attention scores.

    Returns ``(a_v, a_s)``: per-token column mass (N,) and per-diagonal mass
    (N,) where diagonal offset ``o = query_pos - key_pos`` and the strip's
    last row anchors ``o = N - 1 - col``.
    """
    b, n = a_hat.shape
    a_v = jnp.sum(a_hat, axis=0)
    # Diagonal o collects strip entries (r, c) with (n - b + r) - c == o.
    # Shift each row r so its columns align by offset, then sum rows.
    # offset for (r, c): (n - b + r) - c ∈ [r - b + 1 + ... ] — use a roll-free
    # gather: for row r, contribution to offset o comes from c = n - b + r - o.
    offs = jnp.arange(n)
    rows = jnp.arange(b)
    cols = (n - b) + rows[:, None] - offs[None, :]
    valid = (cols >= 0) & (cols < n)
    gathered = jnp.take_along_axis(
        a_hat, jnp.clip(cols, 0, n - 1), axis=1)
    a_s = jnp.sum(jnp.where(valid, gathered, 0.0), axis=0)
    return a_v, a_s


def token_sets_to_block_sets(v_keep: jnp.ndarray, s_keep: jnp.ndarray,
                             block_size: int):
    """Quantize token-level column/diagonal selections to block granularity."""
    n = v_keep.shape[0]
    nb = n // block_size
    col_active = jnp.any(v_keep.reshape(nb, block_size), axis=1)
    # diagonal offsets quantize to block offsets; mark both straddled blocks
    lo = jnp.any(s_keep.reshape(nb, block_size), axis=1)
    hi = jnp.concatenate([lo[1:], jnp.zeros((1,), bool)])
    off_active = lo | hi
    return col_active, off_active


def search_vertical_slash_pattern(q: jnp.ndarray, k: jnp.ndarray,
                                  gamma: float,
                                  block_size: int) -> jnp.ndarray:
    """Algorithm 5, block-granular output: (NB, NB) causal block mask."""
    n = k.shape[0]
    nb = n // block_size
    a_hat = strip_scores(q, k, block_size)
    a_v, a_s = vertical_slash_direction_scores(a_hat)
    v_keep = cumulative_topk_mask(a_v, gamma)
    s_keep = cumulative_topk_mask(a_s, gamma)
    col_active, off_active = token_sets_to_block_sets(
        v_keep, s_keep, block_size)
    # Always keep the main block diagonal (local blocks) and the sink column —
    # required for a well-defined softmax on every query row.
    off_active = off_active.at[0].set(True)
    col_active = col_active.at[0].set(True)
    return vertical_block_mask(nb, col_active) | slash_block_mask(
        nb, off_active)


def search_vertical_slash_from_strip(a_hat: jnp.ndarray, gamma: float,
                                     block_size: int) -> jnp.ndarray:
    """Same as above but from a pre-computed strip (shared with Algorithm 3)."""
    n = a_hat.shape[-1]
    nb = n // block_size
    a_v, a_s = vertical_slash_direction_scores(a_hat)
    v_keep = cumulative_topk_mask(a_v, gamma)
    s_keep = cumulative_topk_mask(a_s, gamma)
    col_active, off_active = token_sets_to_block_sets(
        v_keep, s_keep, block_size)
    off_active = off_active.at[0].set(True)
    col_active = col_active.at[0].set(True)
    return vertical_block_mask(nb, col_active) | slash_block_mask(
        nb, off_active)
