"""Baseline sparse-attention pattern generators the paper compares against.

  * FlashAttention-2  — exact dense attention (the causal mask itself).
  * MInference        — per-head pattern with dynamically re-estimated
    vertical-slash indices (we use its default vertical-slash configuration,
    as the paper does — §6.1).
  * FlexPrefill       — pooled-Q/pooled-K query-aware block estimation with
    cumulative-threshold selection, falling back to vertical-slash for
    "structured" heads (Lai et al., 2025).

These produce (H, NB, NB) block masks consumed by the same sparse kernel, so
accuracy/latency comparisons isolate the *pattern policy* — exactly the
paper's experimental design.  The pooled estimator here is also the subject
of the paper's §3 critique (token-alignment loss, extreme smoothing), which
``benchmarks/bench_pooling_estimation.py`` quantifies.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.patterns import (
    causal_block_mask,
    cumulative_topk_mask,
    dense_block_mask,
)
from repro.core.vertical_slash import search_vertical_slash_pattern


def flash_attention_mask(num_heads: int, nb: int) -> jnp.ndarray:
    """Dense (causal) pattern for every head."""
    return jnp.broadcast_to(dense_block_mask(nb)[None],
                            (num_heads, nb, nb))


def minference_head_mask(qh: jnp.ndarray, kh: jnp.ndarray, *, gamma: float,
                         block_size: int) -> jnp.ndarray:
    """MInference default config for a single head (qh, kh: (N, D))."""
    return search_vertical_slash_pattern(qh, kh, gamma, block_size)


def minference_masks(q: jnp.ndarray, k: jnp.ndarray, *, gamma: float,
                     block_size: int) -> jnp.ndarray:
    """MInference default config: vertical-slash per head, indices estimated
    from the last query block each call (q, k: (H, N, D))."""
    return jax.vmap(
        lambda qh, kh: minference_head_mask(
            qh, kh, gamma=gamma, block_size=block_size))(q, k)


def pooled_block_scores(q: jnp.ndarray, k: jnp.ndarray,
                        block_size: int) -> jnp.ndarray:
    """FlexPrefill's estimator: softmax(pool(Q)·pool(K)ᵀ/√d) over kv blocks.

    q, k: (N, D) single head.  Returns (NB, NB) row-stochastic scores over
    the causal region.
    """
    n, d = q.shape
    nb = n // block_size
    pq = jnp.mean(q.reshape(nb, block_size, d), axis=1)
    pk = jnp.mean(k.reshape(nb, block_size, d), axis=1)
    logits = (pq @ pk.T) / jnp.sqrt(jnp.asarray(d, jnp.float32))
    causal = causal_block_mask(nb)
    logits = jnp.where(causal, logits, -jnp.inf)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.where(causal, jnp.exp(logits - m), 0.0)
    return p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)


def flexprefill_head_mask(qh: jnp.ndarray, kh: jnp.ndarray, *, gamma: float,
                          block_size: int) -> jnp.ndarray:
    """FlexPrefill block mask for a single head (qh, kh: (N, D))."""
    scores = pooled_block_scores(qh, kh, block_size)
    keep = cumulative_topk_mask(scores, gamma)                # per-row γ
    nb = scores.shape[0]
    keep = keep | jnp.eye(nb, dtype=bool)                     # local block
    return keep & causal_block_mask(nb)


def flexprefill_masks(q: jnp.ndarray, k: jnp.ndarray, *, gamma: float,
                      block_size: int) -> jnp.ndarray:
    """Query-aware block mask per head: per q-block cumulative-γ selection
    over pooled block scores (q, k: (H, N, D))."""
    return jax.vmap(
        lambda qh, kh: flexprefill_head_mask(
            qh, kh, gamma=gamma, block_size=block_size))(q, k)
