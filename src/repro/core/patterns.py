"""Block-sparse pattern algebra.

All sparse patterns in this framework are **block-granular** boolean masks of
shape ``(num_q_blocks, num_kv_blocks)`` with ``True`` = "compute this
(q_block, kv_block) tile".  Block size is 128 on TPU (MXU/VMEM alignment —
DESIGN.md §3); the paper's token-granular Triton patterns are mapped onto this
grid.

Conventions:
  * q blocks index rows, kv blocks index columns;
  * causal prefill masks satisfy ``M[i, j] = False`` for ``j > i``;
  * "slash" diagonals are indexed by offset ``o = i - j ∈ [0, NB)``.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np


def num_blocks(seq_len: int, block_size: int) -> int:
    if seq_len % block_size:
        raise ValueError(
            f"seq_len {seq_len} not divisible by block_size {block_size}; "
            "pad sequences to a block multiple before attention")
    return seq_len // block_size


def causal_block_mask(nb_q: int, nb_kv: int | None = None) -> jnp.ndarray:
    """Lower-triangular block mask (diagonal blocks included)."""
    nb_kv = nb_q if nb_kv is None else nb_kv
    i = jnp.arange(nb_q)[:, None]
    j = jnp.arange(nb_kv)[None, :]
    return j <= i + (nb_kv - nb_q)


def dense_block_mask(nb_q: int, nb_kv: int | None = None,
                     causal: bool = True) -> jnp.ndarray:
    nb_kv = nb_q if nb_kv is None else nb_kv
    if causal:
        return causal_block_mask(nb_q, nb_kv)
    return jnp.ones((nb_q, nb_kv), dtype=bool)


def sliding_window_block_mask(nb: int, window_blocks: int,
                              sink_blocks: int = 1) -> jnp.ndarray:
    """Causal sliding window (plus attention-sink blocks) at block granularity.

    A window of ``w`` blocks keeps diagonals 0..w-1; sink blocks keep the
    first ``sink_blocks`` kv block columns (StreamingLLM-style, used by the
    SWA long-decode variant — DESIGN.md §6).
    """
    i = jnp.arange(nb)[:, None]
    j = jnp.arange(nb)[None, :]
    causal = j <= i
    window = (i - j) < window_blocks
    sink = j < sink_blocks
    return causal & (window | sink)


def segment_block_mask(nb: int, seg_blocks: int) -> jnp.ndarray:
    """Block-diagonal segment-isolation mask for packed prefill.

    ``nb`` blocks are split into contiguous segments of ``seg_blocks``; a
    q block may only see kv blocks of its own segment.  ANDed with the
    causal mask this makes a packed multi-prompt launch attention-equivalent
    to independent per-prompt launches (positions are per-segment; the
    pattern dictionary is still updated jointly — see serving docs).
    """
    if seg_blocks <= 0 or nb % seg_blocks:
        raise ValueError(
            f"segment of {seg_blocks} blocks does not tile {nb} blocks")
    seg = jnp.arange(nb) // seg_blocks
    return seg[:, None] == seg[None, :]


def vertical_block_mask(nb: int, col_active: jnp.ndarray) -> jnp.ndarray:
    """Expand active kv-block columns ``(NB,) bool`` into a causal mask."""
    m = jnp.broadcast_to(col_active[None, :], (nb, nb))
    return m & causal_block_mask(nb)


def slash_block_mask(nb: int, offset_active: jnp.ndarray) -> jnp.ndarray:
    """Expand active block diagonals ``(NB,) bool`` (offset o = i - j)."""
    i = jnp.arange(nb)[:, None]
    j = jnp.arange(nb)[None, :]
    off = i - j
    valid = off >= 0
    off = jnp.clip(off, 0, nb - 1)
    return jnp.take(offset_active, off) & valid


def a_shape_block_mask(nb: int, sink_blocks: int,
                       local_blocks: int) -> jnp.ndarray:
    """MInference 'A-shape': attention sink columns + local window."""
    return sliding_window_block_mask(nb, local_blocks, sink_blocks)


def block_mask_density(mask: jnp.ndarray) -> jnp.ndarray:
    """Fraction of *causal* blocks that are computed (the speedup proxy)."""
    nb_q, nb_kv = mask.shape[-2:]
    causal = causal_block_mask(nb_q, nb_kv)
    total = jnp.sum(causal)
    return jnp.sum(mask & causal, axis=(-2, -1)) / total


def expand_block_mask(mask: jnp.ndarray, block_size: int) -> jnp.ndarray:
    """Block mask → token mask (for the jnp reference path and tests)."""
    return jnp.repeat(jnp.repeat(mask, block_size, axis=-2),
                      block_size, axis=-1)


def cumulative_topk_mask(scores: jnp.ndarray, gamma: float) -> jnp.ndarray:
    """Select the minimal set of entries whose mass reaches ``gamma``.

    Implements the paper's cumulative-threshold selection (Algorithm 2 lines
    5-8 / Algorithm 5): sort descending, take the shortest prefix with
    cumulative sum ≥ γ.  Works along the last axis; ``scores`` need not be
    normalized (they are normalized internally).
    """
    s = scores / jnp.maximum(jnp.sum(scores, axis=-1, keepdims=True), 1e-12)
    order = jnp.argsort(-s, axis=-1)
    sorted_s = jnp.take_along_axis(s, order, axis=-1)
    csum = jnp.cumsum(sorted_s, axis=-1)
    # keep entries strictly before the threshold crossing, plus the crosser
    keep_sorted = (csum - sorted_s) < gamma
    keep = jnp.zeros_like(keep_sorted)
    keep = jnp.put_along_axis(keep, order, keep_sorted, axis=-1,
                              inplace=False)
    return keep


def indices_to_mask(indices: jnp.ndarray, size: int) -> jnp.ndarray:
    """index_to_mask from the paper: scatter an index set into a bool mask."""
    mask = jnp.zeros((size,), dtype=bool)
    return mask.at[indices].set(True)


def active_block_table(mask: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Per-q-block active kv-block index lists for the splash kernel.

    Returns ``(indices, counts)`` where ``indices[i, :counts[i]]`` are the kv
    blocks computed for q block ``i`` (padded with the last valid index so the
    kernel's clamped loads stay in-bounds).  Host-side helper (numpy) used to
    *stage* scalar-prefetch operands; the in-graph equivalent lives in
    kernels/ops.py.
    """
    nb_q, nb_kv = mask.shape
    counts = mask.sum(axis=1).astype(np.int32)
    width = int(max(counts.max(), 1))
    indices = np.zeros((nb_q, width), dtype=np.int32)
    for i in range(nb_q):
        idx = np.nonzero(mask[i])[0]
        if len(idx) == 0:
            idx = np.array([0])
        indices[i, : len(idx)] = idx
        indices[i, len(idx):] = idx[-1]
    return indices, counts
