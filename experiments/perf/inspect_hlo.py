import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import re, jax
from repro.launch.steps import build_step
from repro.launch.mesh import make_production_mesh

arch, shape = sys.argv[1], sys.argv[2]
mesh = make_production_mesh()
b = build_step(arch, shape, mesh)
with mesh:
    compiled = jax.jit(b.fn, in_shardings=b.in_shardings).lower(*b.args).compile()
txt = compiled.as_text()
out = f"experiments/perf/{arch}__{shape}.hlo"
open(out, "w").write(txt)
# print collective lines w/ shapes
for line in txt.splitlines():
    l = line.strip()
    m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}/ ]+?)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)(?:-start)?\(", l)
    if m:
        print(m.group(2), m.group(1)[:120])
print("saved", out)
